"""Llama-3.2-Vision-90B backbone: cross-attention image layers every 5th
layer [hf:meta-llama/Llama-3.2-11B-Vision family; unverified].  Vision
frontend is a stub: input_specs() provides precomputed tile/patch
embeddings (1601 tokens/image, d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    vision_tokens=1601,
    rope_theta=5e5,
    notes="cross-attn layers replace self-attn at positions 4,9,... (DESIGN §5)",
)
