"""The paper's own workloads: prime sieve + polynomial multiplication.

Not an LM architecture: this config records the stream-program shapes used
by the faithful reproduction (benchmarks/bench_primes.py, bench_polymul.py).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class StreamExampleConfig:
    name: str = "paper-stream"
    primes_limit: int = 20000        # the paper's `primes`
    primes_x3_limit: int = 60000     # the paper's `primes_x3`
    primes_block: int = 256
    primes_per_cell: int = 16
    poly_power: int = 6              # Fateman (1+x+y+z)^k
    poly_limbs_small: int = 4        # `stream`
    poly_limbs_big: int = 12         # `stream_big` (x100000000001)
    poly_terms_per_cell: int = 8
    poly_x_chunks: int = 4


CONFIG = StreamExampleConfig()
