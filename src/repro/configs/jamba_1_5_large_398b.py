"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].  Pattern period 8 (attention at position 4, as in
the paper); MoE every other layer.  SSD stands in for Mamba-1 (DESIGN §5).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, every_k_layers=2),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    rope_theta=None or 10000.0,
    notes="hybrid 1:7 attn:mamba; MoE every 2nd layer",
)
