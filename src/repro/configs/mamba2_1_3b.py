"""Mamba2-1.3B: attention-free SSD [arXiv:2405.21060; unverified].
d_inner=4096 (expand 2), 64 heads x head_dim 64, state 128."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,      # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,           # no MLP: pure Mamba blocks
    vocab_size=50280,
    block_pattern=("mamba",),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    tie_embeddings=True,
)
