"""MusicGen-medium backbone: decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  The EnCodec frontend is a stub: input_specs()
provides precomputed frame embeddings (sum of the 4 codebook embeddings);
the head predicts one codebook (vocab 2048)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    embeds_input=True,
)
