"""Llama-4-Maverick 400B-A17B: MoE 128e top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E family; unverified].
MoE every 2nd layer (HF interleave_moe_layer_step=2) with one shared
expert; dense layers use d_ff=16384 (HF intermediate_size_mlp), experts
d_ff=8192 (the assigned figure).  Totals ~402B params, ~17B active."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=202048,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  num_shared_experts=1, every_k_layers=2),
    rope_theta=5e5,
    notes="full attention in all layers (no chunked-local variant)",
)
