"""Architecture + shape configuration.

One ``ArchConfig`` per assigned architecture (``src/repro/configs/<id>.py``)
plus the paper's own example config.  Shapes (train_4k / prefill_32k /
decode_32k / long_500k) are global and paired per-arch via
``applicable_shapes``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

# Pipeline-training backward modes (``pipeline_backward`` on
# TrainConfig / ``backward`` on PipelineConfig): "autodiff" lets
# jax.grad transpose the forward tick plan; "planned" executes the
# combined plan's B units as first-class scheduled work (true 1F1B —
# the custom-VJP FutureEvaluator path).  Canonical definition lives in
# repro.core.schedules (the schedule layer owns the modes); re-exported
# here so config-level code never imports the executor.
from repro.core.schedules import BACKWARD_MODES as PIPELINE_BACKWARD_MODES  # noqa: F401


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    every_k_layers: int = 1  # MoE replaces the MLP in layers where (i % k == k-1)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    num_groups: int = 1  # B/C groups (GVA)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # block pattern, repeated to cover num_layers; entries: "attn" | "mamba"
    block_pattern: tuple[str, ...] = ("attn",)
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm_nonparam
    rope_theta: float = 10000.0
    cross_attn_every: int = 0  # >0: every k-th layer is cross-attention (VLM)
    vision_tokens: int = 0     # stubbed frontend sequence length
    embeds_input: bool = False # audio/vlm stub: model takes embeddings directly
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-5
    # per-op implementation dispatch (repro.kernels.get_impl): "xla" runs
    # the pure-jnp paths, "pallas" the fused kernels (interpret-emulated
    # off-TPU), "auto" picks pallas on TPU and xla elsewhere.
    kernels: str = "xla"
    # sub-quadratic attention available => long_500k applicable
    notes: str = ""

    @property
    def attn_free(self) -> bool:
        return "attn" not in self.block_pattern and self.cross_attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        return "mamba" in self.block_pattern

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.pattern_period == 0
        return self.num_layers // self.pattern_period

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class DecodePipelineConfig:
    """Stream-shaped serving knobs (see repro.serve.engine.StreamEngine).

    The decode loop runs as a ``Stream.feedback`` program: the
    transformer's layer groups split into ``num_cells`` pipeline cells,
    the batch splits into ``microbatches`` in-flight items (the feedback
    lag — steady state is bubble-free when it reaches handoff x devices),
    and one device program executes ``round_steps`` decode steps with up
    to ``admit_per_round`` freshly prefilled requests admitted into
    retired slots *inside* the plan.
    """

    num_cells: int = 4        # layer-group pipeline cells (must divide groups)
    microbatches: int = 4     # in-flight request microbatches = feedback lag
    schedule: str = "gpipe"   # gpipe | one_f_one_b | interleaved
    interleave: int = 1       # virtual stages per device (interleaved only)
    round_steps: int = 8      # decode steps per device-program invocation
    admit_per_round: int = 4  # in-plan admission buffer depth
    axis_name: str = "pod"    # mesh axis the cells shard over
    # kernel dispatch override for the decode hot path; None inherits the
    # model's ArchConfig.kernels knob.
    kernels: str | None = None


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")  # skip for pure full-attention archs
    return shapes


# smoke-test reduction: same family, tiny dims
def smoke_config(cfg: ArchConfig) -> ArchConfig:
    period = cfg.pattern_period
    num_layers = 2 * period if cfg.cross_attn_every == 0 else 2 * cfg.cross_attn_every
    kw: dict[str, Any] = dict(
        num_layers=num_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        vision_tokens=min(cfg.vision_tokens, 16) if cfg.vision_tokens else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=8, chunk_size=8
        )
    return cfg.with_overrides(**kw)
