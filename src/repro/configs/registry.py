"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    ArchConfig,
    ShapeCell,
    applicable_shapes,
    smoke_config,
)

_MODULES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen1.5-4b": "qwen1_5_4b",
    "olmo-1b": "olmo_1b",
    "internlm2-20b": "internlm2_20b",
    "qwen3-32b": "qwen3_32b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "mamba2-1.3b": "mamba2_1_3b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_cells() -> list[tuple[str, str]]:
    """Every assigned (arch, shape) cell, with inapplicable shapes skipped."""
    cells = []
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in applicable_shapes(cfg):
            cells.append((arch_id, shape))
    return cells


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ArchConfig",
    "ShapeCell",
    "all_cells",
    "applicable_shapes",
    "get_config",
    "smoke_config",
]
