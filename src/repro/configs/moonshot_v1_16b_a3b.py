"""Moonlight-16B-A3B (moonshot): DeepSeek-V3-style MoE 64e top-6 + 2
shared experts [hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2, every_k_layers=1),
    rope_theta=5e4,
)
