"""Loop-aware post-SPMD HLO text analysis.

XLA's ``cost_analysis()`` counts while-loop bodies once and its CPU
bytes-accessed model ignores fusion boundaries.  This parser rebuilds both
metrics from the compiled HLO text:

* **Loop multipliers** — jax scans lower to ``while`` ops annotated with
  ``backend_config={"known_trip_count":{"n":...}}``; every computation
  reachable as a while body/condition inherits ``parent × trip``.
* **Collective bytes** — output-shape bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, × loop multiplier,
  × per-kind ring-traffic factor.
* **HBM traffic** — Σ over instructions of (operands + output) bytes,
  with fusions counted at their boundary (internal ops live in
  registers/VMEM — the TPU model), dynamic-update-slice counted at the
  update size (in-place on TPU), and layout/metadata ops skipped.

Per-device numbers (post-SPMD shapes are per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "call", "partition-id",
    "replica-id", "rng-bit-generator",
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

TRAFFIC_MULTIPLIER = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: list[str]
    attrs: str

    @property
    def out_bytes(self) -> int:
        return shape_bytes(self.shape)


def _split_rhs(rhs: str) -> tuple[str, str, str, str] | None:
    """rhs = '<shape> <opcode>(<operands>)<attrs>' -> parts."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                shape, rest = rhs[: i + 1], rhs[i + 1 :]
                break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape, rest = rhs[:sp], rhs[sp:]
    rest = rest.strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    depth, start = 0, rest.find("(")
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            operands_str = rest[start + 1 : i]
            attrs = rest[i + 1 :]
            break
    else:
        return None
    return shape, opcode, operands_str, attrs


def parse_module(text: str):
    """Returns (computations: {name: [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: list[Instr] | None = None
    for line in text.splitlines():
        header = _COMP_HEADER_RE.match(line)
        if header:
            name = header.group(2)
            comps[name] = []
            cur = comps[name]
            if header.group(1):
                entry = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        parts = _split_rhs(m.group(2))
        if parts is None:
            continue
        shape, opcode, operands_str, attrs = parts
        operands = re.findall(r"%([\w.\-]+)", operands_str)
        cur.append(Instr(m.group(1), shape, opcode, operands, attrs))
    return comps, entry


def loop_multipliers(comps, entry) -> dict[str, float]:
    """Computation name -> product of enclosing while trip counts."""
    mult = {entry: 1.0}
    # whiles: (parent, body, cond, trip)
    edges = []
    for comp_name, instrs in comps.items():
        for ins in instrs:
            if ins.opcode != "while":
                continue
            body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
            cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
            trip_m = _TRIP_RE.search(ins.attrs)
            trip = float(trip_m.group(1)) if trip_m else 1.0
            if body and cond:
                edges.append((comp_name, body.group(1), cond.group(1), trip))
    changed = True
    while changed:
        changed = False
        for parent, body, cond, trip in edges:
            if parent in mult:
                for child, m in ((body, mult[parent] * trip), (cond, mult[parent])):
                    if mult.get(child) != m:
                        mult[child] = m
                        changed = True
    return mult


def _instr_hbm_bytes(ins: Instr, name_bytes: dict[str, int]) -> int:
    if ins.opcode in _SKIP_OPS:
        return 0
    out = ins.out_bytes
    if ins.opcode == "dynamic-update-slice":
        # in-place on TPU: traffic = update read + write
        upd = name_bytes.get(ins.operands[1], 0) if len(ins.operands) > 1 else 0
        return 2 * upd
    if ins.opcode == "broadcast":
        return out  # read side is negligible
    ops = sum(name_bytes.get(o, 0) for o in ins.operands)
    return out + ops


def analyze_hlo(text: str) -> dict[str, Any]:
    """Loop-aware collective bytes + HBM traffic (per device)."""
    comps, entry = parse_module(text)
    mult = loop_multipliers(comps, entry)

    coll_bytes = {k: 0.0 for k in COLLECTIVE_KINDS}
    coll_counts = {k: 0 for k in COLLECTIVE_KINDS}
    coll_static = {k: 0 for k in COLLECTIVE_KINDS}
    top: list[tuple[float, str, str, float, str]] = []
    hbm = 0.0

    for comp_name, m in mult.items():
        instrs = comps.get(comp_name)
        if instrs is None:
            continue
        name_bytes = {i.name: i.out_bytes for i in instrs}
        for ins in instrs:
            base = ins.opcode
            if base.endswith("-start"):
                base = base[: -len("-start")]
            if base.endswith("-done"):
                continue
            if base in COLLECTIVE_KINDS:
                coll_bytes[base] += ins.out_bytes * m
                coll_counts[base] += int(m)
                coll_static[base] += 1
                opm = re.search(r'op_name="([^"]+)"', ins.attrs)
                top.append((
                    ins.out_bytes * m * TRAFFIC_MULTIPLIER[base],
                    base, ins.shape[:60], m,
                    (opm.group(1)[-120:] if opm else ""),
                ))
            hbm += _instr_hbm_bytes(ins, name_bytes) * m
    top.sort(reverse=True)

    weighted = sum(coll_bytes[k] * TRAFFIC_MULTIPLIER[k] for k in COLLECTIVE_KINDS)
    return {
        "collective_bytes_by_kind": coll_bytes,
        "collective_counts_dynamic": coll_counts,
        "collective_counts_static": coll_static,
        "collective_weighted_bytes": weighted,
        "hbm_traffic_bytes": hbm,
        "num_computations": len(comps),
        "num_loops": sum(1 for v in mult.values() if v > 1),
        "top_collectives": [
            {"gib": round(b / 2**30, 2), "kind": k, "shape": s,
             "mult": m, "op": o}
            for b, k, s, m, o in top[:12]
        ],
    }


# ---------------------------------------------------------------------------
# Conditional-region isolation (the emit-split HLO assertion)
# ---------------------------------------------------------------------------

# Computation-reference attributes and whether following them crosses
# into a conditional's branch (the "guarded" edges).  An SPMD program is
# one module for every device; what distinguishes "device d never runs
# the LM head" is that the head ops live only inside conditional branch
# computations whose predicate (a plan column) is false on device d.
_CALL_ATTRS = (
    ("to_apply=%?([\\w.\\-]+)", False),
    ("body=%?([\\w.\\-]+)", False),
    ("condition=%?([\\w.\\-]+)", False),
    # Fusions reference their body as calls=%fused_computation (the
    # textual form XLA emits); missing this edge would leave fusion
    # bodies unreachable and silently classify fused ops as guarded.
    ("calls=\\{([^}]*)\\}", False),
    ("calls=%?([\\w.\\-]+)", False),
    ("called_computations=\\{([^}]*)\\}", False),
    ("true_computation=%?([\\w.\\-]+)", True),
    ("false_computation=%?([\\w.\\-]+)", True),
    ("branch_computations=\\{([^}]*)\\}", True),
)


def _call_edges(instrs):
    """Yield (callee, guarded) for every computation reference."""
    for ins in instrs:
        for pattern, guarded in _CALL_ATTRS:
            for m in re.finditer(pattern, ins.attrs):
                for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
                    yield name, guarded


def unguarded_matches(text: str, match) -> tuple[int, int]:
    """Count instructions satisfying ``match(Instr)`` in the module, and
    how many of those sit in a computation reachable from the entry
    *without* crossing into a conditional branch.

    Returns ``(total, unguarded)``.  ``unguarded == 0`` with ``total >
    0`` means every matching op is region-isolated behind a conditional
    — combined with a plan whose gating column is zero on a device, that
    device's executed tick body never contains the op.
    """
    comps, entry = parse_module(text)
    edges: dict[str, list[tuple[str, bool]]] = {}
    for name, instrs in comps.items():
        edges[name] = list(_call_edges(instrs))
    # BFS over non-guarded edges only.
    unguarded_comps: set[str] = set()
    frontier = [entry] if entry else []
    while frontier:
        name = frontier.pop()
        if name in unguarded_comps or name not in comps:
            continue
        unguarded_comps.add(name)
        for callee, guarded in edges.get(name, ()):
            if not guarded:
                frontier.append(callee)
    total = unguarded = 0
    for name, instrs in comps.items():
        for ins in instrs:
            if not match(ins):
                continue
            total += 1
            if name in unguarded_comps:
                unguarded += 1
    return total, unguarded


def slab_scatter_counts(text: str, slab_bytes: int) -> tuple[int, int]:
    """Count slab-sized cache writes: scatter / dynamic-update-slice ops
    whose *output* is at least ``slab_bytes`` (the full KV-cache slab for
    one layer group — a row write's output is the same slab shape, but a
    functional ``cache.at[idx, pos].set(rows)`` materializes the whole
    updated slab as a new buffer, which is what shows up here).

    Returns ``(total, unguarded)`` with the same guarded/unguarded split
    as :func:`unguarded_matches`: an op inside a conditional branch does
    not run on devices where the branch predicate is false.  The fused
    Pallas decode-attention path performs the row substitution inside
    the kernel, so its steady tick carries strictly fewer slab-sized
    scatters than the XLA path — asserted comparatively (pallas < xla)
    rather than as an absolute zero, because the in-plan admission
    buffer legitimately writes freshly prefilled rows.
    """

    def is_slab_write(ins) -> bool:
        if ins.opcode not in ("scatter", "dynamic-update-slice"):
            return False
        return ins.out_bytes >= slab_bytes

    return unguarded_matches(text, is_slab_write)


def fused_region_present(text: str, marker: str) -> bool:
    """True iff any instruction's ``op_name`` metadata contains
    ``marker``.  The Pallas ops wrap their ``pallas_call`` in
    ``jax.named_scope(FUSION_SCOPE)``; the scope name survives into the
    compiled module's op_name metadata, so presence of the marker means
    the fused kernel (or, in interpret mode, its lowered emulation) is
    structurally in the executed program — and absence in an XLA-mode
    module is the negative control.
    """
    for m in re.finditer(r'op_name="([^"]*)"', text):
        if marker in m.group(1):
            return True
    return False


def head_matmul_conditional_only(text: str, logits_width: int) -> bool:
    """True iff the module contains at least one logits-width matmul and
    every one of them is conditional-guarded (see
    :func:`unguarded_matches`).  The serving emit-split acceptance
    check: with the plan's ``emit`` column nonzero only on the final
    pipeline device, a guarded head matmul is structurally absent from
    every other device's executed tick body."""

    def is_head_dot(ins) -> bool:
        if ins.opcode not in ("dot", "custom-call"):
            return False
        if ins.opcode == "custom-call" and "matmul" not in ins.attrs.lower():
            return False
        dims = [
            int(d)
            for _, ds in _SHAPE_RE.findall(ins.shape)
            if ds
            for d in ds.split(",")
        ]
        return logits_width in dims

    total, unguarded = unguarded_matches(text, is_head_dot)
    return total > 0 and unguarded == 0
