"""Exact analytic FLOP accounting per (arch × shape).

XLA's ``cost_analysis`` counts while-loop bodies once, so any scan left
rolled (attention KV stream, SSD chunk stream, microbatch loop)
undercounts.  The dry-run unrolls the layer scan and scales the microbatch
loop, but the inner streaming loops stay rolled by design — so the
*compute* roofline term uses this module's exact matmul accounting, and
the HLO figure is recorded alongside as a cross-check
(EXPERIMENTS.md §Roofline documents the method).

Conventions: one MAC = 2 FLOPs; fwd-only for inference; training =
fwd + backward (2×) + remat recompute (1× when remat enabled) = 4× fwd
for all layer compute, 3× (no remat) for the unrematerialized head/loss.
Attention is charged full S² (our chunked impl does not skip fully-masked
causal blocks — a recorded inefficiency that §Perf attacks); the
causal-skip variant halves it.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import ssm as S
from repro.models.transformer import block_plans, effective_period


def _attn_layer_flops(cfg, tokens, s_kv, *, causal_skip=False):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * tokens * d * (h + 2 * kv) * dh + 2 * tokens * h * dh * d
    score_factor = 0.5 if causal_skip else 1.0
    attn = 2 * 2 * tokens * s_kv * h * dh * score_factor  # QK^T + PV
    return proj, attn


def _cross_attn_layer_flops(cfg, tokens, batch):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    vt = cfg.vision_tokens
    proj = (
        2 * tokens * d * h * dh                   # q
        + 2 * batch * vt * d * 2 * kv * dh        # k,v over vision tokens
        + 2 * tokens * h * dh * d                 # out
    )
    attn = 2 * 2 * tokens * vt * h * dh
    return proj, attn


def _mlp_flops(cfg, tokens, d_ff):
    return 2 * 3 * tokens * cfg.d_model * d_ff


def _moe_flops(cfg, tokens):
    moe = cfg.moe
    router = 2 * tokens * cfg.d_model * moe.num_experts
    experts = 2 * 3 * tokens * moe.top_k * cfg.d_model * moe.d_ff_expert
    shared = (
        2 * 3 * tokens * cfg.d_model * moe.d_ff_expert * moe.num_shared_experts
    )
    return router + experts + shared


def _ssd_layer_flops(cfg, tokens, batch):
    ssm = cfg.ssm
    d_inner, h, conv_dim, proj_dim = S.ssm_dims(cfg, ssm)
    n, p, g = ssm.state_dim, ssm.head_dim, ssm.num_groups
    q = min(ssm.chunk_size, tokens // max(batch, 1))
    proj = 2 * tokens * cfg.d_model * proj_dim + 2 * tokens * d_inner * cfg.d_model
    conv = 2 * tokens * conv_dim * ssm.conv_width
    # intra-chunk: cb (Q×N×Q per group) + y_intra (Q×Q×P per head)
    intra = 2 * tokens * q * (g * n + h * p)
    # states + y_inter: two (N×P) contractions per token-head
    inter = 2 * 2 * tokens * h * n * p
    norm = 5 * tokens * d_inner
    return proj + conv + intra + inter + norm


def forward_flops(
    cfg: ArchConfig,
    tokens: int,
    batch: int,
    s_kv: int,
    *,
    causal_skip: bool = False,
    with_head: bool = True,
) -> dict[str, float]:
    """One forward pass, token count ``tokens``, KV context ``s_kv``."""
    plans = block_plans(cfg)
    groups = cfg.num_layers // effective_period(cfg)
    proj = attn = ffn = ssd = 0.0
    for plan in plans:
        if plan.mixer == "attn":
            p_, a_ = _attn_layer_flops(cfg, tokens, s_kv, causal_skip=causal_skip)
            proj += p_
            attn += a_
        elif plan.mixer == "cross_attn":
            p_, a_ = _cross_attn_layer_flops(cfg, tokens, batch)
            proj += p_
            attn += a_
        else:
            ssd += _ssd_layer_flops(cfg, tokens, batch)
        if plan.ffn == "dense":
            ffn += _mlp_flops(cfg, tokens, cfg.d_ff)
        elif plan.ffn == "moe":
            ffn += _moe_flops(cfg, tokens)
    out = {
        "proj": proj * groups,
        "attn": attn * groups,
        "ffn": ffn * groups,
        "ssd": ssd * groups,
        "head": 2 * tokens * cfg.d_model * cfg.vocab_size if with_head else 0.0,
    }
    out["total"] = sum(out.values())
    return out


def step_flops(cfg: ArchConfig, shape: ShapeCell, *, remat=True, causal_skip=False):
    """Analytic FLOPs of the lowered step for this cell (global)."""
    if shape.kind == "train":
        f = forward_flops(
            cfg, shape.tokens, shape.global_batch, shape.seq_len,
            causal_skip=causal_skip,
        )
        mult = 4.0 if remat else 3.0  # fwd + bwd(2x) [+ remat fwd]
        body = (f["proj"] + f["attn"] + f["ffn"] + f["ssd"]) * mult
        head = f["head"] * 3.0  # head/loss not rematerialized
        return {"total": body + head, "forward": f}
    if shape.kind == "prefill":
        f = forward_flops(
            cfg, shape.tokens, shape.global_batch, shape.seq_len,
            causal_skip=causal_skip,
        )
        return {"total": f["total"], "forward": f}
    # decode: one token per sequence, context s_kv
    f = forward_flops(
        cfg, shape.global_batch, shape.global_batch, shape.seq_len,
        causal_skip=False,
    )
    return {"total": f["total"], "forward": f}


# ---------------------------------------------------------------------------
# Per-kernel decode rooflines (the serving hot path)
# ---------------------------------------------------------------------------
#
# The decode tick is bandwidth-bound: one token per row means every
# matmul streams its full weight matrix for a (B, d) activation, and the
# attention read streams the KV slab.  The per-kernel terms below model
# the two fused Pallas ops (decode_attention, emit_norm_logits) and the
# XLA baselines they replace — the XLA decode-attention term carries the
# extra slab write that the functional ``cache.at[idx, pos].set(rows)``
# materializes, which is exactly the traffic the fused kernel removes.


def _itemsize(cfg: ArchConfig) -> int:
    import jax.numpy as jnp

    return jnp.dtype(cfg.dtype).itemsize


def decode_kernel_rooflines(
    cfg: ArchConfig, *, batch: int, kv_len: int, mode: str = "pallas"
) -> dict[str, dict[str, float]]:
    """Roofline terms for one invocation of each decode-path kernel op.

    ``decode_attention`` covers one attention layer's single-token step
    over a ``batch``-row microbatch with KV context ``kv_len`` (the
    cache slab's allocated length — decode streams the whole slab, rows
    past the valid length are masked, not skipped).  ``emit_norm_logits``
    covers the final-norm → logits epilogue for the same microbatch.

    Returns ``{op: {"flops", "hbm_bytes", "intensity"}}``; intensity is
    FLOPs per HBM byte — compare against the machine balance point to
    see both ops sit deep in the bandwidth-bound regime.  ``mode`` picks
    the traffic model: "xla" charges the functional slab write
    (scatter materializes the updated KV slab) and the materialized
    norm intermediate; "pallas" charges row-granularity cache writes
    and the fused epilogue's single pass over the head weights.
    """
    it = _itemsize(cfg)
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    v = cfg.vocab_size

    # -- decode_attention: QK^T + PV over the slab (matmul convention,
    # matching _attn_layer_flops; softmax/mask flops are negligible).
    attn_flops = 2 * 2 * batch * kv_len * h * dh
    slab = batch * kv_len * kv * dh * it          # one of K or V
    rows = batch * kv * dh * it                   # one new row per item
    qout = 2 * batch * h * dh * it                # q read + ctx write
    attn_bytes = 2 * slab + 2 * rows + qout       # read both slabs + new rows
    if mode == "pallas":
        attn_bytes += 2 * rows                    # row-granularity cache write
    else:
        attn_bytes += 2 * slab                    # functional slab materialize
    # -- emit_norm_logits: rmsnorm/layernorm + (B,d)x(d,V) head matmul.
    emit_flops = 2 * batch * d * v + 6 * batch * d
    emit_bytes = d * v * it + batch * d * it + batch * v * 4  # w + x + f32 out
    if mode != "pallas":
        emit_bytes += 2 * batch * d * it          # normed intermediate r/w

    out = {}
    for op, fl, by in (
        ("decode_attention", float(attn_flops), float(attn_bytes)),
        ("emit_norm_logits", float(emit_flops), float(emit_bytes)),
    ):
        out[op] = {"flops": fl, "hbm_bytes": by, "intensity": fl / by}
    return out


def predicted_tick_seconds(
    cfg: ArchConfig,
    *,
    batch: int,
    kv_len: int,
    peak_flops_per_second: float,
    hbm_bytes_per_second: float,
    mode: str = "pallas",
) -> dict[str, float]:
    """Roofline lower bound for one full-model decode step + emit.

    Sums, over all layers, max(compute, bandwidth) time for (a) the
    weight-streaming matmuls (projections/MLP/SSD — FLOPs from
    :func:`forward_flops`, bytes = parameter bytes, the decode regime's
    dominant term), and (b) the per-kernel decode terms from
    :func:`decode_kernel_rooflines` for every attention layer, plus one
    emit epilogue.  Returns ``{"attn", "emit", "weights", "total"}``
    seconds; ``mode`` selects the xla/pallas traffic model so
    bench_serve can report achieved-vs-predicted per tick for both.
    """
    from repro.models.params import param_count
    from repro.models.transformer import model_layout

    def t(flops: float, bytes_: float) -> float:
        return max(flops / peak_flops_per_second, bytes_ / hbm_bytes_per_second)

    per = decode_kernel_rooflines(cfg, batch=batch, kv_len=kv_len, mode=mode)
    n_attn = sum(1 for b in cfg.block_pattern if b == "attn") * (
        cfg.num_layers // cfg.pattern_period
    )
    ka = per["decode_attention"]
    ke = per["emit_norm_logits"]
    attn_s = n_attn * t(ka["flops"], ka["hbm_bytes"])
    emit_s = t(ke["flops"], ke["hbm_bytes"])

    # Weight-streaming body: all non-attention-score, non-head compute.
    f = forward_flops(cfg, batch, batch, kv_len, with_head=False)
    body_flops = f["proj"] + f["ffn"] + f["ssd"]
    body_bytes = (
        param_count(model_layout(cfg)) - cfg.d_model * cfg.vocab_size
    ) * _itemsize(cfg)
    weights_s = t(body_flops, max(body_bytes, 0))

    return {
        "attn": attn_s,
        "emit": emit_s,
        "weights": weights_s,
        "total": attn_s + emit_s + weights_s,
    }
