"""Exact analytic FLOP accounting per (arch × shape).

XLA's ``cost_analysis`` counts while-loop bodies once, so any scan left
rolled (attention KV stream, SSD chunk stream, microbatch loop)
undercounts.  The dry-run unrolls the layer scan and scales the microbatch
loop, but the inner streaming loops stay rolled by design — so the
*compute* roofline term uses this module's exact matmul accounting, and
the HLO figure is recorded alongside as a cross-check
(EXPERIMENTS.md §Roofline documents the method).

Conventions: one MAC = 2 FLOPs; fwd-only for inference; training =
fwd + backward (2×) + remat recompute (1× when remat enabled) = 4× fwd
for all layer compute, 3× (no remat) for the unrematerialized head/loss.
Attention is charged full S² (our chunked impl does not skip fully-masked
causal blocks — a recorded inefficiency that §Perf attacks); the
causal-skip variant halves it.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import ssm as S
from repro.models.transformer import block_plans, effective_period


def _attn_layer_flops(cfg, tokens, s_kv, *, causal_skip=False):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * tokens * d * (h + 2 * kv) * dh + 2 * tokens * h * dh * d
    score_factor = 0.5 if causal_skip else 1.0
    attn = 2 * 2 * tokens * s_kv * h * dh * score_factor  # QK^T + PV
    return proj, attn


def _cross_attn_layer_flops(cfg, tokens, batch):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    vt = cfg.vision_tokens
    proj = (
        2 * tokens * d * h * dh                   # q
        + 2 * batch * vt * d * 2 * kv * dh        # k,v over vision tokens
        + 2 * tokens * h * dh * d                 # out
    )
    attn = 2 * 2 * tokens * vt * h * dh
    return proj, attn


def _mlp_flops(cfg, tokens, d_ff):
    return 2 * 3 * tokens * cfg.d_model * d_ff


def _moe_flops(cfg, tokens):
    moe = cfg.moe
    router = 2 * tokens * cfg.d_model * moe.num_experts
    experts = 2 * 3 * tokens * moe.top_k * cfg.d_model * moe.d_ff_expert
    shared = (
        2 * 3 * tokens * cfg.d_model * moe.d_ff_expert * moe.num_shared_experts
    )
    return router + experts + shared


def _ssd_layer_flops(cfg, tokens, batch):
    ssm = cfg.ssm
    d_inner, h, conv_dim, proj_dim = S.ssm_dims(cfg, ssm)
    n, p, g = ssm.state_dim, ssm.head_dim, ssm.num_groups
    q = min(ssm.chunk_size, tokens // max(batch, 1))
    proj = 2 * tokens * cfg.d_model * proj_dim + 2 * tokens * d_inner * cfg.d_model
    conv = 2 * tokens * conv_dim * ssm.conv_width
    # intra-chunk: cb (Q×N×Q per group) + y_intra (Q×Q×P per head)
    intra = 2 * tokens * q * (g * n + h * p)
    # states + y_inter: two (N×P) contractions per token-head
    inter = 2 * 2 * tokens * h * n * p
    norm = 5 * tokens * d_inner
    return proj + conv + intra + inter + norm


def forward_flops(
    cfg: ArchConfig,
    tokens: int,
    batch: int,
    s_kv: int,
    *,
    causal_skip: bool = False,
    with_head: bool = True,
) -> dict[str, float]:
    """One forward pass, token count ``tokens``, KV context ``s_kv``."""
    plans = block_plans(cfg)
    groups = cfg.num_layers // effective_period(cfg)
    proj = attn = ffn = ssd = 0.0
    for plan in plans:
        if plan.mixer == "attn":
            p_, a_ = _attn_layer_flops(cfg, tokens, s_kv, causal_skip=causal_skip)
            proj += p_
            attn += a_
        elif plan.mixer == "cross_attn":
            p_, a_ = _cross_attn_layer_flops(cfg, tokens, batch)
            proj += p_
            attn += a_
        else:
            ssd += _ssd_layer_flops(cfg, tokens, batch)
        if plan.ffn == "dense":
            ffn += _mlp_flops(cfg, tokens, cfg.d_ff)
        elif plan.ffn == "moe":
            ffn += _moe_flops(cfg, tokens)
    out = {
        "proj": proj * groups,
        "attn": attn * groups,
        "ffn": ffn * groups,
        "ssd": ssd * groups,
        "head": 2 * tokens * cfg.d_model * cfg.vocab_size if with_head else 0.0,
    }
    out["total"] = sum(out.values())
    return out


def step_flops(cfg: ArchConfig, shape: ShapeCell, *, remat=True, causal_skip=False):
    """Analytic FLOPs of the lowered step for this cell (global)."""
    if shape.kind == "train":
        f = forward_flops(
            cfg, shape.tokens, shape.global_batch, shape.seq_len,
            causal_skip=causal_skip,
        )
        mult = 4.0 if remat else 3.0  # fwd + bwd(2x) [+ remat fwd]
        body = (f["proj"] + f["attn"] + f["ffn"] + f["ssd"]) * mult
        head = f["head"] * 3.0  # head/loss not rematerialized
        return {"total": body + head, "forward": f}
    if shape.kind == "prefill":
        f = forward_flops(
            cfg, shape.tokens, shape.global_batch, shape.seq_len,
            causal_skip=causal_skip,
        )
        return {"total": f["total"], "forward": f}
    # decode: one token per sequence, context s_kv
    f = forward_flops(
        cfg, shape.global_batch, shape.global_batch, shape.seq_len,
        causal_skip=False,
    )
    return {"total": f["total"], "forward": f}
