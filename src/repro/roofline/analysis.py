"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms, in seconds, per (arch × shape × mesh):

    compute    = HLO_FLOPs          / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes_accessed / (chips × 819e9  B/s HBM)
    collective = collective_bytes   /  (chips × 50e9 B/s per-link ICI)

``cost_analysis()`` on the compiled executable supplies FLOPs and bytes.
XLA reports them for the *partitioned per-device module*; we detect which
convention is in play by magnitude against MODEL_FLOPS and normalize to
per-device (see ``normalize_flops``).  Collective bytes are not in
cost_analysis: we parse the post-SPMD HLO text and sum output-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (per-device traffic approximation; an
all-reduce moves ~2× its operand in a ring, folded into a configurable
multiplier per kind).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

import numpy as np

# --- v5e hardware constants (per chip) --------------------------------------
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9  # ~ 4 links/chip on v5e; we charge one link (worst case)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ring all-reduce moves 2(n-1)/n ≈ 2× the buffer per device;
# all-gather / reduce-scatter move (n-1)/n ≈ 1× the *global* buffer;
# permute and all-to-all move ~1× of what they carry.
_TRAFFIC_MULTIPLIER = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# e.g. "bf16[16,512,8192]{2,1,0}" possibly inside a tuple "(bf16[...], u32[])"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:%[\w.\-]+|[\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
    r"((?:%?)(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?)\(",
    re.MULTILINE,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, Any]:
    """Sum output-shape bytes per collective kind from HLO text.

    '-done' ops are skipped (the '-start' carries the shape) and so are
    ops inside fusions (collectives are never fused).  Bytes from
    collectives inside while-loop bodies are multiplied by the trip count
    when XLA left a known trip count marker; scan-lowered loops carry it.
    """
    out = {k: 0 for k in _COLLECTIVE_KINDS}
    counts = {k: 0 for k in _COLLECTIVE_KINDS}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, opname, kind = m.group(1), m.group(2), m.group(3)
        if opname.endswith("-done"):
            continue
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    total = sum(
        out[k] * _TRAFFIC_MULTIPLIER[k] for k in _COLLECTIVE_KINDS
    )
    return {"bytes_by_kind": out, "counts": counts, "weighted_bytes": total}


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort scan trip counts (trip_count= attributes)."""
    return [int(x) for x in re.findall(r"trip_count=(\d+)", hlo_text)]


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per device, raw from cost_analysis
    hlo_bytes: float          # per device (scaled by microbatch factor)
    collective_bytes: float   # per device (weighted, scaled)
    model_flops: float        # 6ND train / 2ND inference (global)
    analytic_flops: float = 0.0  # exact accounting (repro.roofline.analytic)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self) -> "RooflineTerms":
        # compute term from the exact analytic count (scan-proof); HLO raw
        # kept for cross-checking.  Memory/collective terms are HLO-derived.
        flops_per_dev = (
            self.analytic_flops / self.chips
            if self.analytic_flops
            else self.hlo_flops
        )
        self.compute_s = flops_per_dev / PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW_PER_LINK
        return self

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled-compute: remat/padding/redundancy waste."""
        total = self.analytic_flops or self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute roofline fraction (MFU against the bound)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def to_json(self) -> dict:
        return {
            **dataclasses.asdict(self),
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, active_params: int) -> float:
    """6·N·D for training, 2·N·D for inference steps (N = active params)."""
    if shape.kind == "train":
        return 6.0 * active_params * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * active_params * shape.tokens
    # decode: one token per sequence
    return 2.0 * active_params * shape.global_batch


def active_param_count(cfg, layout) -> int:
    """Parameter count with MoE experts scaled by top_k/num_experts."""
    import jax

    from repro.models.params import is_spec

    total = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
        layout, is_leaf=is_spec
    )[0]:
        n = int(np.prod(spec.shape))
        keystr = jax.tree_util.keystr(path)
        if "experts" in spec.logical_axes:
            frac = cfg.moe.top_k / cfg.moe.num_experts
            n = int(n * frac)
        if "embed'" in keystr or "embedding" in keystr:
            pass  # embeddings are gathers, not matmuls; keep for 6ND convention
        total += n
    return total


def normalize_flops(raw_flops: float, chips: int, model_flops_: float) -> float:
    """Return per-device FLOPs regardless of XLA's reporting convention."""
    if model_flops_ <= 0:
        return raw_flops
    # If raw is within 1.5 decades of the *global* figure, it's global.
    if raw_flops > model_flops_ / 30:
        return raw_flops / chips
    return raw_flops
