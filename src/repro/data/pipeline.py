"""Data pipeline: deterministic synthetic token streams with prefetch.

* **Step-keyed determinism** — batch(step) is a pure function of
  (seed, step), so checkpoint replay after a fault sees identical data
  (required by :mod:`repro.train.fault`), and every host generates only
  its own shard (no host-0 broadcast).
* **Prefetch = the stream's future tail** — ``PrefetchIterator`` keeps N
  batches in flight on host futures while the device computes, the
  paper's Cons(hd, tl: Future) applied to the input pipeline.
* A file-backed source (memory-mapped token file) is provided for real
  corpora; the synthetic source is a Zipf-ish unigram LM with enough
  structure that loss decreases measurably (used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.future import HostFuture

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    vocab_size: int = 512
    kind: str = "synthetic"  # synthetic | file
    path: str | None = None


class SyntheticSource:
    """Zipf unigram + local bigram structure (learnable but nontrivial)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        # fixed random bigram successor table: next token is succ[t] w.p. 0.5
        self.succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size)

    def batch(self, step: int) -> PyTree:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        shape = (cfg.global_batch, cfg.seq_len + 1)
        iid = rng.choice(cfg.vocab_size, size=shape, p=self.probs)
        toks = iid.copy()
        use_bigram = rng.random(shape) < 0.5
        toks[:, 1:] = np.where(
            use_bigram[:, 1:], self.succ[toks[:, :-1]], iid[:, 1:]
        )
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class FileSource:
    """Memory-mapped flat token file (uint16/uint32), step-keyed slicing."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=dtype, mode="r")

    def batch(self, step: int) -> PyTree:
        cfg = self.cfg
        need = cfg.global_batch * (cfg.seq_len + 1)
        start = (step * need) % max(1, len(self.tokens) - need)
        window = np.asarray(self.tokens[start : start + need], np.int32)
        window = window.reshape(cfg.global_batch, cfg.seq_len + 1)
        return {"tokens": window[:, :-1], "labels": window[:, 1:]}


def make_source(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticSource(cfg)
    if cfg.kind == "file":
        return FileSource(cfg)
    raise ValueError(cfg.kind)


def host_shard(batch: PyTree, process_index=None, process_count=None) -> PyTree:
    """Each host materializes only its rows of the global batch."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count

    def shard(x):
        rows = x.shape[0]
        assert rows % pc == 0
        per = rows // pc
        return x[pi * per : (pi + 1) * per]

    return jax.tree.map(shard, batch)


class PrefetchIterator:
    """Keep ``depth`` future batches in flight (double buffering)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.depth = depth
        self._next_step = start_step
        self._queue: list[tuple[int, HostFuture]] = []
        self._fill()

    def _fill(self):
        while len(self._queue) < self.depth:
            step = self._next_step
            self._queue.append(
                (step, HostFuture(lambda s=step: self.source.batch(s)))
            )
            self._next_step += 1

    def __iter__(self) -> Iterator[PyTree]:
        return self

    def __next__(self) -> PyTree:
        step, fut = self._queue.pop(0)
        batch = fut.force()  # Await.result — usually already done
        self._fill()
        return batch

    def seek(self, step: int):
        """Reposition after checkpoint restore."""
        self._queue.clear()
        self._next_step = step
        self._fill()
