"""Monotonic heartbeat file: the external-supervisor detection channel.

A wedged worker cannot report itself — detection must be external.  The
worker writes ``"<step> <wall_time>"`` after every completed step/round;
an external supervisor (or a test) reads the file's age and SIGKILLs a
worker whose heartbeat is stale, landing it in the restart path.  Both
halves live here so the writer and the detector can never drift on
format.
"""
from __future__ import annotations

import os
import time


class Heartbeat:
    """Per-step heartbeat writer.  ``path=None`` disables (no-op)."""

    def __init__(self, path: str | None):
        self.path = path

    def beat(self, step: int) -> None:
        if not self.path:
            return
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}\n")

    @staticmethod
    def read(path: str) -> tuple[int, float]:
        """Returns (last step, wall time of its beat)."""
        with open(path) as f:
            step_s, t_s = f.read().split()
        return int(step_s), float(t_s)

    @staticmethod
    def is_stale(path: str, max_age_s: float, now: float | None = None) -> bool:
        """True when the worker should be presumed wedged: no heartbeat
        file yet, or its last beat is older than ``max_age_s``."""
        if not os.path.exists(path):
            return True
        _, t = Heartbeat.read(path)
        now = time.time() if now is None else now
        return (now - t) > max_age_s
