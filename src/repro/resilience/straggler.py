"""Straggler detection: EMA step-time tracking with a policy hook.

On a real pod the action on a detected straggler is to cordon the slow
host and re-shard (see :mod:`repro.train.elastic`); the detector and the
policy hook are the reusable halves, so they live here and the action
stays a callback.
"""
from __future__ import annotations

from typing import Callable


class StragglerTracker:
    """Flag steps slower than ``factor`` × the EMA of past step times.

    ``observe`` returns True (and invokes ``on_straggler(step, ratio)``)
    when the step is a straggler; the first observation only seeds the
    EMA.  A straggler's own time still folds into the EMA afterwards, so
    a persistently slow regime stops flagging once it becomes the norm
    — the tracker detects *deviation*, not absolute slowness.
    """

    def __init__(
        self,
        factor: float = 2.0,
        ema: float = 0.9,
        on_straggler: Callable[[int, float], None] | None = None,
    ):
        self.factor = factor
        self.ema = ema
        self.on_straggler = on_straggler
        self.count = 0
        self._ema_step_time: float | None = None

    def observe(self, step: int, dt: float) -> bool:
        if self._ema_step_time is None:
            self._ema_step_time = dt
            return False
        straggler = dt > self.factor * self._ema_step_time
        if straggler:
            self.count += 1
            if self.on_straggler:
                self.on_straggler(step, dt / self._ema_step_time)
        a = self.ema
        self._ema_step_time = a * self._ema_step_time + (1 - a) * dt
        return straggler
