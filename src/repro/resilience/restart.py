"""Bounded restart budget with exponential backoff.

Unbounded retry turns a deterministic failure into a hang; zero retry
turns a transient one into an outage.  The policy is the knob set, the
budget is the mutable per-run state — loops create a fresh
:class:`RestartBudget` per run (or per round, for round-scoped retry)
so exhaustion never leaks across independent work.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    max_restarts: int = 3
    backoff_seconds: float = 0.0  # first retry's delay; 0 = immediate
    backoff_factor: float = 2.0   # multiplier per subsequent retry


class RestartBudget:
    """Mutable restart state for one run under a :class:`RestartPolicy`."""

    def __init__(self, policy: RestartPolicy):
        self.policy = policy
        self.restarts = 0

    @property
    def exhausted(self) -> bool:
        return self.restarts >= self.policy.max_restarts

    def admit(self) -> bool:
        """Consume one restart; False when the budget is exhausted (the
        caller should re-raise instead of retrying)."""
        if self.exhausted:
            return False
        self.restarts += 1
        return True

    def next_delay(self) -> float:
        """Backoff before the restart just admitted (0.0 by default).
        The first admitted restart waits ``backoff_seconds``, each one
        after that ``backoff_factor`` × the previous delay."""
        base = self.policy.backoff_seconds
        if base <= 0 or self.restarts == 0:
            return 0.0
        return base * self.policy.backoff_factor ** (self.restarts - 1)
