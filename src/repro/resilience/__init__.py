"""Shared resilience machinery: the failure side of the Future substitution.

The paper's move — substituting Future for Lazy — makes failure a
first-class value: a forced future can fail, time out, or be retried,
and the *flow* (not a single force point) is where failure must
propagate.  This package is the generic runbook both long-lived loops in
this repo consume:

* :mod:`repro.train.fault` — ``ResilientLoop`` wraps the train step
  (checkpoint/restart, heartbeats, stragglers, preemption windows).
* :mod:`repro.serve.supervisor` — ``ServeSupervisor`` wraps a serving
  engine (round snapshot/restore, watchdog deadline, numerics poisoning
  detection, graceful SIGTERM drain).

Modules:

* :mod:`repro.resilience.injection` — the fail-injector protocol and the
  ``OneShotInjector`` used by every chaos test: a callable invoked at
  each step/round boundary that raises (or mutates the target) to
  simulate a fault, exactly once.
* :mod:`repro.resilience.heartbeat` — monotonic per-step heartbeat file
  + staleness reader (the external-supervisor detection side).
* :mod:`repro.resilience.straggler` — EMA step-time tracker with a
  policy callback.
* :mod:`repro.resilience.restart` — bounded restart budget with
  exponential backoff.
"""
from repro.resilience.heartbeat import Heartbeat
from repro.resilience.injection import InjectedFault, OneShotInjector
from repro.resilience.restart import RestartBudget, RestartPolicy
from repro.resilience.straggler import StragglerTracker

__all__ = [
    "Heartbeat",
    "InjectedFault",
    "OneShotInjector",
    "RestartBudget",
    "RestartPolicy",
    "StragglerTracker",
]
