"""Fail-injector protocol: deterministic fault simulation at boundaries.

An injector is any ``Callable[[int], None]`` (optionally accepting the
supervised object as a second argument) invoked by a resilient loop at
each step/round boundary *before* the step's work.  To inject a fault it
raises — :class:`InjectedFault` by convention, so tests and logs can
tell simulated failures from real ones — or mutates its target (e.g.
NaN-poisoning a cache, sending a signal to the current process).

Injection is the *test protocol* of this package: the production loops
never require an injector, but accept one so the chaos batteries can
prove the restart path is bitwise-reproducing (see
``tests/test_serve_resilience.py`` and the train restart tests).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable


class InjectedFault(RuntimeError):
    """A simulated failure raised by a fail injector."""


class OneShotInjector:
    """Fire ``action`` exactly once, at step/round index ``at``.

    One-shot is the shape every restart test needs: the fault fires on
    the first attempt of round ``at`` and *not* on its replay, so a
    bounded-restart loop provably recovers.  ``action`` receives the
    supervised target when the caller passes one (the serve supervisor
    hands its engine over; ``ResilientLoop`` calls with the step index
    only and ``action`` is invoked with ``None``).
    """

    def __init__(self, at: int, action: Callable[[Any], None]):
        self.at = at
        self.action = action
        self.fired = False

    def __call__(self, step: int, target: Any = None) -> None:
        if step == self.at and not self.fired:
            self.fired = True
            self.action(target)


def call_injector(injector, step: int, target: Any = None) -> None:
    """Invoke ``injector`` with (step, target) or (step) as it accepts.

    Keeps the one-argument train-loop injector signature
    (``fail_injector(step)``) and the two-argument serving signature
    (``injector(round, engine)``) interchangeable — the loops call this
    instead of hand-checking arity.
    """
    if injector is None:
        return
    try:
        sig = inspect.signature(injector)
        two = len(sig.parameters) >= 2
    except (TypeError, ValueError):  # builtins / C callables: assume 1-arg
        two = False
    if two:
        injector(step, target)
    else:
        injector(step)
