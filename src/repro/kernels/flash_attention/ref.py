"""Pure-jnp oracle for the flash-attention kernel (fp32 math, GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, H, Sq, dh)
    k: jnp.ndarray,  # (B, KV, Sk, dh)
    v: jnp.ndarray,  # (B, KV, Sk, dh)
    *,
    causal: bool = True,
    q_offset: int = 0,
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = softmax_scale if softmax_scale is not None else dh**-0.5
    qf = q.reshape(b, kv, g, sq, dh).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf)
    if causal:
        q_pos = jnp.arange(sq) + q_offset
        kv_pos = jnp.arange(sk)
        mask = kv_pos[None, :] <= q_pos[:, None]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vf)
    return out.reshape(b, h, sq, dh).astype(q.dtype)
