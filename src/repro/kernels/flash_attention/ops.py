"""Jit'd public wrapper for the flash-attention kernel.

Handles layout adaptation (model code uses (B, S, H, dh); the kernel uses
(B, H, S, dh)), GQA head mapping, block-size selection, and the
interpret-mode fallback on CPU (the kernel body executes via the Pallas
interpreter — bit-accurate logic, no Mosaic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, dh)
    k: jnp.ndarray,  # (B, Sk, KV, dh)
    v: jnp.ndarray,  # (B, Sk, KV, dh)
    *,
    causal: bool = True,
    softmax_scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool | None = None,
    **_ignored,
) -> jnp.ndarray:
    """Drop-in for repro.models.layers.attention(impl=...)."""
    if interpret is None:
        interpret = default_interpret()
    qt = jnp.swapaxes(q, 1, 2)  # (B, H, Sq, dh)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(
        qt, kt, vt,
        causal=causal, softmax_scale=softmax_scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)
