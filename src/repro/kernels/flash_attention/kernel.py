"""Flash attention as a Pallas TPU kernel (forward).

The online-softmax KV loop is the paper's bounded stream at the VMEM
level: the innermost grid dimension walks KV blocks; Pallas's grid
pipelining double-buffers the next block's HBM→VMEM DMA while the MXU
works on the current one — the Cons(hd, tl: Future) of the memory system.

Layout: q (B, H, Sq, dh), k/v (B, KV, Sk, dh) — GQA is handled in the
BlockSpec index maps (kv head = q head // group), so grouped KV is never
replicated in HBM.

Grid: (B, H, Sq/blk_q, Sk/blk_k); scratch (m, l, acc) carries softmax
state across the sequential innermost dimension.  Causal blocks entirely
above the diagonal skip their compute via ``pl.when`` (the DMA still
flows — on TPU the bandwidth is hidden by the pipeline; see §Perf for the
triangular-grid variant that removes the wasted blocks altogether).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # blocks
    o_ref,                # output block
    m_ref, l_ref, acc_ref,  # scratch (persist across the kv grid dim)
    *,
    causal: bool,
    softmax_scale: float,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: skip blocks strictly above the diagonal.
    q_start = qi * block_q
    k_start = ki * block_k
    needed = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(needed if isinstance(needed, bool) else needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * softmax_scale  # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bk)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        # Fully-masked rows (causal prefix) have l == 0: emit zeros.
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "softmax_scale", "block_q", "block_k", "interpret"
    ),
)
def flash_attention_bhsd(
    q: jnp.ndarray,  # (B, H, Sq, dh)
    k: jnp.ndarray,  # (B, KV, Sk, dh)
    v: jnp.ndarray,  # (B, KV, Sk, dh)
    *,
    causal: bool = True,
    softmax_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    kv, sk = k.shape[1], k.shape[2]
    assert h % kv == 0
    group = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = softmax_scale if softmax_scale is not None else dh**-0.5

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        softmax_scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, dh), lambda bb, hh, qi, ki: (bb, hh, qi, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, dh),
                lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, dh),
                lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, dh), lambda bb, hh, qi, ki: (bb, hh, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # m
            pltpu.VMEM((block_q, 1), jnp.float32),  # l
            pltpu.VMEM((block_q, dh), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
