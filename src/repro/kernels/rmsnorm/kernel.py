"""Fused RMSNorm Pallas kernel.

Fuses the reduce (mean of squares), rsqrt, and scale into one VMEM pass —
one HBM read + one write per element, vs the unfused lowering's 3–4
round-trips (the fp32 upcast copy, the variance reduce re-read, and the
normalize re-read).  Rows are tiled (block_rows, d): d stays whole per
block (the reduction axis must live in one kernel instance).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale_ref[...]).astype(
        o_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("eps", "block_rows", "interpret")
)
def rmsnorm_pallas(
    x: jnp.ndarray,        # (rows, d) — callers flatten leading dims
    scale: jnp.ndarray,    # (d,) fp32
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale.astype(jnp.float32))
