"""Jit'd wrapper: leading-dim flattening + interpret fallback on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-5,
            interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in for repro.models.layers.rmsnorm(params, x)."""
    if interpret is None:
        interpret = default_interpret()
    lead = x.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    d = x.shape[-1]
    # pick the largest block that divides rows (pow2 walk-down)
    block = 256
    while block > 1 and rows % block != 0:
        block //= 2
    out = rmsnorm_pallas(
        x.reshape(rows, d), scale, eps=eps, block_rows=block,
        interpret=interpret,
    )
    return out.reshape(*lead, d)
