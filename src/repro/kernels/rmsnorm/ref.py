"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """x: (..., d); scale: (d,) fp32.  fp32 math, output in x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * scale).astype(x.dtype)
