"""Pure-jnp oracle: the decode emit's final-norm -> logits step, verbatim.

Replicates what ``make_decode_emit`` runs unfused: ``_norm`` (rmsnorm or
OLMo's non-parametric layernorm) followed by ``layers.logits`` (tied or
untied head, fp32 cast) and the ``[:, 0, :]`` squeeze.  The fused kernel
is gated on bitwise equality with this function.
"""
from __future__ import annotations

import jax.numpy as jnp


def emit_norm_logits_ref(
    x: jnp.ndarray,        # (B, 1, d) — the emit's hidden state
    w: jnp.ndarray,        # (d, V) untied head | (V, d) tied embedding
    *,
    norm: str,             # "rmsnorm" | "layernorm_nonparam"
    scale=None,            # (d,) rmsnorm scale (None for layernorm)
    eps: float = 1e-5,
    tied: bool = False,
    interpret: bool | None = None,  # accepted for signature parity
) -> jnp.ndarray:
    from repro.models import layers as L

    if norm == "rmsnorm":
        xn = L.rmsnorm({"scale": scale}, x, eps)
    elif norm == "layernorm_nonparam":
        xn = L.layernorm_nonparam(x, eps)
    else:
        raise ValueError(norm)
    eq = "bsd,vd->bsv" if tied else "bsd,dv->bsv"
    return jnp.einsum(eq, xn, w).astype(jnp.float32)[:, 0, :]
