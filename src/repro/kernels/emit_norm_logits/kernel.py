"""Fused emit-epilogue Pallas kernel: final norm + LM-head matmul.

``make_decode_emit`` closes the decode feedback loop with final-norm →
logits → sample; unfused, the norm round-trips the (B, d) hidden state
through HBM (fp32 upcast, variance reduce, normalize) before the head
matmul reads it again.  This kernel tiles the vocab axis and recomputes
the (tiny, B×d) normalization per tile in VMEM, so each weight tile is
read once and the hidden state never materializes a normalized copy in
HBM.  The per-tile recompute is bitwise-stable: every logit is an
independent d-length dot, so vocab tiling cannot change its reduction
order — outputs are bitwise equal to the unfused path (ref.py).

Supports both norms the configs use (rmsnorm and OLMo's non-parametric
layernorm) and both head layouts (untied ``(d, V)`` / tied embedding
``(V, d)``), mirroring ``layers.logits``'s einsum + fp32 cast exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _normalize(x_ref, scale_ref, *, norm: str, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (B, d)
    if norm == "rmsnorm":
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        xn = x * lax.rsqrt(var + eps) * scale_ref[...]
    else:  # layernorm_nonparam
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        xn = (x - mu) * lax.rsqrt(var + eps)
    return xn.astype(x_ref.dtype)


# The dot's output stays in the input dtype (as in ``layers.logits``) and
# the fp32 upcast happens OUTSIDE the pallas_call: chaining the upcast
# directly onto the in-kernel dot lets XLA's float-normalization cleanup
# elide the low-precision rounding of the dot output, silently breaking
# bitwise parity with the unfused path for bf16 models.

def _emit_kernel_scaled(x_ref, scale_ref, w_ref, o_ref, *, norm, eps, tied):
    xn = _normalize(x_ref, scale_ref, norm=norm, eps=eps)
    eq = "bd,vd->bv" if tied else "bd,dv->bv"
    o_ref[...] = jnp.einsum(eq, xn, w_ref[...])


def _emit_kernel_plain(x_ref, w_ref, o_ref, *, norm, eps, tied):
    xn = _normalize(x_ref, None, norm=norm, eps=eps)
    eq = "bd,vd->bv" if tied else "bd,dv->bv"
    o_ref[...] = jnp.einsum(eq, xn, w_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=("norm", "eps", "tied", "block_v", "interpret"),
)
def emit_norm_logits_pallas(
    x: jnp.ndarray,           # (B, d)
    w: jnp.ndarray,           # (d, V) untied | (V, d) tied
    scale: jnp.ndarray | None,  # (d,) fp32 (rmsnorm only)
    *,
    norm: str,
    eps: float = 1e-5,
    tied: bool = False,
    block_v: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, d = x.shape
    v = w.shape[0] if tied else w.shape[1]
    block_v = min(block_v, v)
    while block_v > 1 and v % block_v != 0:
        block_v //= 2
    grid = (v // block_v,)
    x_spec = pl.BlockSpec((b, d), lambda j: (0, 0))
    w_spec = (
        pl.BlockSpec((block_v, d), lambda j: (j, 0))
        if tied
        else pl.BlockSpec((d, block_v), lambda j: (0, j))
    )
    o_spec = pl.BlockSpec((b, block_v), lambda j: (0, j))
    out_shape = jax.ShapeDtypeStruct((b, v), x.dtype)
    if norm == "rmsnorm":
        out = pl.pallas_call(
            functools.partial(
                _emit_kernel_scaled, norm=norm, eps=eps, tied=tied
            ),
            grid=grid,
            in_specs=[x_spec, pl.BlockSpec((d,), lambda j: (0,)), w_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(x, scale.astype(jnp.float32), w)
    else:
        out = pl.pallas_call(
            functools.partial(
                _emit_kernel_plain, norm=norm, eps=eps, tied=tied
            ),
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(x, w)
    return out.astype(jnp.float32)
