"""Jit'd public wrapper for the emit-epilogue fusion.

The ``pallas_emit_norm_logits`` name scope is the structural marker
``roofline.hlo_parse.fused_region_present`` asserts on in compiled
round HLO — it survives into op_name metadata even under the Pallas
interpreter, where no custom-call exists to look for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.emit_norm_logits.kernel import emit_norm_logits_pallas

FUSION_SCOPE = "pallas_emit_norm_logits"


def emit_norm_logits(
    x: jnp.ndarray,  # (B, 1, d)
    w: jnp.ndarray,  # (d, V) untied head | (V, d) tied embedding
    *,
    norm: str,
    scale=None,
    eps: float = 1e-5,
    tied: bool = False,
    block_v: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Drop-in for the emit's ``_norm`` + ``layers.logits`` + ``[:, 0, :]``
    (bitwise equal to ref.py); returns fp32 logits ``(B, V)``."""
    if interpret is None:
        interpret = default_interpret()
    if norm not in ("rmsnorm", "layernorm_nonparam"):
        raise ValueError(norm)
    with jax.named_scope(FUSION_SCOPE):
        return emit_norm_logits_pallas(
            x[:, 0], w,
            scale if norm == "rmsnorm" else None,
            norm=norm, eps=eps, tied=tied, block_v=block_v,
            interpret=interpret,
        )
