"""SSD = Pallas intra-chunk kernel + the cross-chunk stream recurrence.

``ssd_chunked_pallas`` mirrors :func:`repro.models.ssm.ssd_chunked` but
computes the per-chunk (intra) work in the kernel; the carried (H,N,P)
state — the paper's future-tail — is combined outside, either with a
sequential ``lax.scan`` (Lazy; default) or an associative scan
(``recurrence="associative"`` — the beyond-paper parallelization: the
decay/state pairs form a semigroup (d2, s2)∘(d1, s1) = (d1·d2, d2·s1+s2)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax import lax

from repro.kernels import default_interpret


def _combine(left, right):
    d1, s1 = left
    d2, s2 = right
    return d1 * d2, d2[..., None, None] * s1 + s2


def ssd_chunked_pallas(
    x, dt, a, b_mat, c_mat, d_skip,
    *,
    chunk: int,
    initial_state=None,
    recurrence: str = "scan",
    interpret: bool | None = None,
):
    """Same contract as repro.models.ssm.ssd_chunked (y, final_state)."""
    from repro.kernels.ssd.kernel import ssd_intra_chunk

    if interpret is None:
        interpret = default_interpret()
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0
    nc = s // chunk

    # (B, S, ...) -> (B*nc, head-major, Q, ...)
    xk = x.reshape(bsz, nc, chunk, h, p).transpose(0, 1, 3, 2, 4).reshape(
        bsz * nc, h, chunk, p
    )
    dtk = dt.reshape(bsz, nc, chunk, h).transpose(0, 1, 3, 2).reshape(
        bsz * nc, h, chunk
    )
    bk = b_mat.reshape(bsz, nc, chunk, g, n).transpose(0, 1, 3, 2, 4).reshape(
        bsz * nc, g, chunk, n
    )
    ck = c_mat.reshape(bsz, nc, chunk, g, n).transpose(0, 1, 3, 2, 4).reshape(
        bsz * nc, g, chunk, n
    )

    y_intra, states, cum = ssd_intra_chunk(
        xk, dtk, bk, ck,
        a.astype(jnp.float32), d_skip.astype(jnp.float32),
        chunk=chunk, interpret=interpret,
    )
    y_intra = y_intra.reshape(bsz, nc, h, chunk, p)
    states = states.reshape(bsz, nc, h, n, p)
    cum = cum.reshape(bsz, nc, h, chunk)
    chunk_decay = jnp.exp(cum[:, :, :, -1])  # (B, nc, H)

    s0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    if recurrence == "associative":
        # prefix-combine all (decay, state) pairs, then shift right by one
        decays = jnp.moveaxis(chunk_decay, 1, 0)  # (nc, B, H)
        sts = jnp.moveaxis(states, 1, 0)  # (nc, B, H, N, P)
        # fold the initial state into the first element
        sts = sts.at[0].add(s0 * decays[0][..., None, None])
        pd, ps = lax.associative_scan(_combine, (decays, sts), axis=0)
        final = ps[-1]
        prev = jnp.concatenate([s0[None], ps[:-1]], axis=0)  # state entering chunk
        prev_states = jnp.moveaxis(prev, 0, 1)  # (B, nc, H, N, P)
    else:
        def step(carry, inp):
            dec, st = inp
            new = carry * dec[..., None, None] + st
            return new, carry

        final, prev = lax.scan(
            step, s0,
            (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
        )
        prev_states = jnp.moveaxis(prev, 0, 1)

    # inter-chunk output: C_i · S_prev · exp(cum_i), shaped (B,nc,H,Q,P)
    hg = h // g
    ch = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), hg, axis=3)
    y_inter = jnp.einsum(
        "bzqhn,bzhnp,bzhq->bzhqp",
        ch.astype(jnp.float32), prev_states, jnp.exp(cum),
    )
    y = y_intra.astype(jnp.float32) + y_inter
    y = y.transpose(0, 1, 3, 2, 4).reshape(bsz, s, h, p).astype(x.dtype)
    return y, final
