"""Pure-jnp oracle for the Mamba-2 SSD chunk kernel.

Naive (non-chunked) recurrence — the ground truth the chunked kernel and
the model's scan implementation must both reproduce:

    s_t = exp(dt_t * a) * s_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . s_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_ref(x, dt, a, b_mat, c_mat, d_skip, *, initial_state=None):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,G,N); d_skip: (H,).

    Returns (y (B,S,H,P) f32, final_state (B,H,N,P) f32).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g
    f32 = jnp.float32
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    bh = jnp.repeat(b_mat, hg, axis=2).astype(f32)  # (B,S,H,N)
    ch = jnp.repeat(c_mat, hg, axis=2).astype(f32)

    s0 = (
        jnp.zeros((bsz, h, n, p), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def step(state, t):
        decay = jnp.exp(dtf[:, t] * a)  # (B,H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dtf[:, t], bh[:, t], xf[:, t]
        )
        y = jnp.einsum("bhn,bhnp->bhp", ch[:, t], state)
        y = y + xf[:, t] * d_skip[None, :, None]
        return state, y

    final, ys = lax.scan(step, s0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1), final
