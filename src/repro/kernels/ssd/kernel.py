"""Mamba-2 SSD intra-chunk Pallas kernel.

One grid instance = (one sequence chunk) × (one head): the per-cell
footprint of the SSD stream.  The cross-chunk state recurrence — the
paper's dependent chain — stays outside (see ops.py), carried either by a
sequential scan (Lazy) or an associative scan (beyond-paper).

Per instance, with Q = chunk, N = state, P = head dim:

    cum   = L_tri @ (dt * a)          (cumulative decay, via MXU matmul —
                                       cumsum has no native TPU lowering)
    decay = exp(cum_i - cum_j) ⊙ tril
    cb    = C @ B^T                   (Q,N)x(N,Q)
    y     = (cb ⊙ decay ⊙ dt_j) @ x   (Q,Q)x(Q,P)
    state = (B ⊙ exp(total-cum) dt)^T @ x   (N,Q)x(Q,P)
    cumout= exp(cum) (for the inter-chunk C·S_prev term outside)

VMEM per instance ≈ Q² + Q(N+2P) floats — 380 KiB at Q=256,N=128,P=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(
    x_ref, dt_ref, b_ref, c_ref, a_ref, dskip_ref,
    y_ref, state_ref, cum_ref,
    *,
    chunk: int,
):
    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, 1) -> (Q,)
    b = b_ref[0, 0].astype(jnp.float32)        # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)        # (Q, N)
    a = a_ref[0, 0]                            # scalar
    d_skip = dskip_ref[0, 0]                   # scalar

    dtc = dt[:, 0]                             # (Q,)
    da = dtc * a                               # (Q,)
    # cumulative (inclusive) decay via lower-triangular matmul
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    cum = jax.lax.dot_general(
        tri.astype(jnp.float32), da[:, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                                     # (Q,)
    total = cum[-1]

    decay = jnp.exp(cum[:, None] - cum[None, :])
    decay = jnp.where(tri, decay, 0.0)
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (Q, Q)
    w = cb * decay * dtc[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (Q, P)
    y = y + x * d_skip

    state_w = jnp.exp(total - cum) * dtc        # (Q,)
    state = jax.lax.dot_general(
        b * state_w[:, None], x,
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                           # (N, P)

    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)
    state_ref[0, 0, :, :] = state
    cum_ref[0, 0, :, 0] = cum


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_intra_chunk(
    x: jnp.ndarray,   # (BC, H, Q, P)  BC = batch*num_chunks
    dt: jnp.ndarray,  # (BC, H, Q)
    b: jnp.ndarray,   # (BC, G, Q, N)
    c: jnp.ndarray,   # (BC, G, Q, N)
    a: jnp.ndarray,   # (H,) negative decay rates (f32)
    d_skip: jnp.ndarray,  # (H,) (f32)
    *,
    chunk: int,
    interpret: bool = False,
):
    """Returns (y (BC,H,Q,P), state (BC,H,N,P) f32, cum (BC,H,Q) f32)."""
    bc, h, q, p = x.shape
    g, n = b.shape[1], b.shape[3]
    hg = h // g
    assert q == chunk

    grid = (bc, h)
    kernel = functools.partial(_ssd_chunk_kernel, chunk=chunk)
    y, state, cum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, hh: (i, hh, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda i, hh: (i, hh, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, hh, _hg=hg: (i, hh // _hg, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, hh, _hg=hg: (i, hh // _hg, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, hh: (hh, 0)),
            pl.BlockSpec((1, 1), lambda i, hh: (hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, hh: (i, hh, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, hh: (i, hh, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda i, hh: (i, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bc, h, q, p), x.dtype),
            jax.ShapeDtypeStruct((bc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bc, h, q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        x,
        dt[..., None],
        b,
        c,
        a[:, None].astype(jnp.float32),
        d_skip[:, None].astype(jnp.float32),
    )
    return y, state, cum[..., 0]
