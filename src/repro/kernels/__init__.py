"""Pallas kernel library + the per-op dispatch registry.

Each op lives in its own package (``kernel.py`` = the Pallas body,
``ops.py`` = the jit'd layout-adapting wrapper, ``ref.py`` = the pure-jnp
oracle the kernel is tested bitwise/tolerance against):

* ``flash_attention``  — tiled online-softmax attention (prefill/train).
* ``rmsnorm``          — fused reduce+rsqrt+scale, one VMEM pass.
* ``ssd``              — Mamba-2 SSD intra-chunk kernel.
* ``decode_attention`` — the serving hot path: fuses the per-tick KV row
  scatter with the single-row attention read, so no updated slab is ever
  materialized in HBM (the row lands in VMEM only).
* ``emit_norm_logits`` — decode-emit epilogue: final norm + LM-head
  matmul in one pass over vocab tiles.

Model code selects implementations through :func:`get_impl` driven by the
``kernels`` config knob (``"xla" | "pallas" | "auto"``) instead of
hard-coding XLA.  ``"auto"`` resolves to ``"pallas"`` on TPU and
``"xla"`` elsewhere; an explicit ``"pallas"`` off-TPU runs the kernels
under the Pallas interpreter (bit-accurate kernel logic, no Mosaic) —
that is what keeps the tier-1 parity batteries runnable on CPU.
"""
from __future__ import annotations

import jax

KERNEL_MODES = ("xla", "pallas", "auto")

# op -> (module path, wrapper attr) for the pallas side; the xla side is
# the op's pure-jnp reference (same call signature).
_PALLAS_IMPLS = {
    "attention": ("repro.kernels.flash_attention.ops", "flash_attention"),
    "rmsnorm": ("repro.kernels.rmsnorm.ops", "rmsnorm"),
    "ssd": ("repro.kernels.ssd.ops", "ssd_chunked_pallas"),
    "decode_attention": (
        "repro.kernels.decode_attention.ops", "fused_decode_attention"
    ),
    "emit_norm_logits": (
        "repro.kernels.emit_norm_logits.ops", "emit_norm_logits"
    ),
}
_XLA_IMPLS = {
    "attention": ("repro.kernels.flash_attention.ref", "attention_ref"),
    "rmsnorm": ("repro.kernels.rmsnorm.ref", "rmsnorm_ref"),
    "ssd": ("repro.kernels.ssd.ref", "ssd_ref"),
    "decode_attention": (
        "repro.kernels.decode_attention.ref", "decode_attention_ref"
    ),
    "emit_norm_logits": (
        "repro.kernels.emit_norm_logits.ref", "emit_norm_logits_ref"
    ),
}

OPS = tuple(_PALLAS_IMPLS)


def on_tpu() -> bool:
    """Single source of the backend autodetect every ops.py used to copy."""
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Pallas interpret-mode default: emulate the kernel off-TPU."""
    return not on_tpu()


def resolve_mode(mode: str | None) -> str:
    """Validate the ``kernels`` knob and collapse ``auto`` to a backend."""
    if mode is None:
        mode = "xla"
    if mode not in KERNEL_MODES:
        raise ValueError(
            f"kernels={mode!r}; expected one of {KERNEL_MODES}"
        )
    if mode == "auto":
        return "pallas" if on_tpu() else "xla"
    return mode


def get_impl(op: str, mode: str = "auto"):
    """The implementation of ``op`` under the ``kernels`` mode.

    ``"pallas"`` returns the kernel's jit'd wrapper (interpret-mode
    off-TPU), ``"xla"`` the pure-jnp reference with the same signature.
    Imports lazily so importing the package never pulls Pallas in.
    """
    table = {"pallas": _PALLAS_IMPLS, "xla": _XLA_IMPLS}[resolve_mode(mode)]
    if op not in table:
        raise ValueError(f"unknown kernel op {op!r}; have {OPS}")
    module_path, attr = table[op]
    import importlib

    return getattr(importlib.import_module(module_path), attr)
