"""Jit'd public wrapper: model layout adaptation + interpret fallback.

Model code hands the decode query as ``(B, 1, H, dh)`` (the S==1 decode
step) and per-sequence ``kv_len`` as ``(B,)`` or ``(B, 1)``; the kernel
wants flat per-row operands.  The ``pallas_decode_attention`` name scope
is the structural marker ``roofline.hlo_parse.fused_region_present``
asserts on in compiled round HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.decode_attention.kernel import decode_attention_pallas

FUSION_SCOPE = "pallas_decode_attention"


def fused_decode_attention(
    q: jnp.ndarray,        # (B, 1, H, dh)
    k_new: jnp.ndarray,    # (B, KV, dh)
    v_new: jnp.ndarray,    # (B, KV, dh)
    k_cache: jnp.ndarray,  # (B, S, KV, dh)
    v_cache: jnp.ndarray,  # (B, S, KV, dh)
    *,
    pos: jnp.ndarray,      # (B,) int32 write positions
    kv_len: jnp.ndarray,   # (B,) or (B, 1) valid KV count after the write
    softmax_scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Drop-in for the slab-update + attention_dense decode path; returns
    the attention context ``(B, 1, H, dh)`` (bitwise equal to ref.py)."""
    if interpret is None:
        interpret = default_interpret()
    b = q.shape[0]
    with jax.named_scope(FUSION_SCOPE):
        out = decode_attention_pallas(
            q[:, 0],
            k_new, v_new, k_cache, v_cache,
            jnp.asarray(pos).reshape(b),
            jnp.asarray(kv_len).reshape(b),
            softmax_scale=softmax_scale,
            interpret=interpret,
        )
    return out[:, None]
