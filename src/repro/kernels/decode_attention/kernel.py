"""Fused decode-attention Pallas kernel: KV row scatter + single-row read.

One decode tick's attention against the cache is, unfused, three HLO
ops per layer: scatter K row into the slab, scatter V row, dense
attention over both updated slabs — the scatters materialize two full
``(B, S, KV, dh)`` copies in HBM whose only consumer is the very next
dot.  This kernel consumes the *pre-update* cache pages plus the new
rows and emits the attention output directly: the updated slab exists
only as a VMEM value (``jnp.where`` against a row iota), never in HBM.
The caller still owns the durable row-level cache write
(:func:`repro.models.transformer.scatter_decode_rows` on the tick
carry) — that write is the row itself, not a slab.

Math replicates :func:`repro.models.layers.attention_dense` op for op
(fp32 scores, post-matmul scale, ``-inf`` prefix mask, ``jax.nn.softmax``,
NaN scrub, fp32 V matmul, cast back) so outputs are **bitwise** equal to
the unfused path — the serving parity batteries assert exactly that.

Grid is one program per batch row; ``pos``/``kv_len`` ride scalar
prefetch (SMEM) since they index nothing in the block maps but gate the
in-VMEM row substitution and the mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_attention_kernel(
    pos_ref, len_ref,            # scalar prefetch: (B,) int32 each
    q_ref,                       # (1, H, dh)
    kn_ref, vn_ref,              # (1, KV, dh) — this step's rows
    kc_ref, vc_ref,              # (1, S, KV, dh) — pre-update cache pages
    o_ref,                       # (1, H, dh)
    *,
    scale: float,
):
    bb = pl.program_id(0)
    pos = pos_ref[bb]
    klen = len_ref[bb]
    kc = kc_ref[0]
    vc = vc_ref[0]
    s, kv, dh = kc.shape
    h = q_ref.shape[1]
    g = h // kv
    # The "scatter" half: substitute the new row at ``pos`` in VMEM only.
    row = lax.broadcasted_iota(jnp.int32, (s, 1, 1), 0)
    k = jnp.where(row == pos, kn_ref[0][None], kc)
    v = jnp.where(row == pos, vn_ref[0][None], vc)
    # The "read" half: attention_dense's exact sequence for Sq=1.
    qg = q_ref[0].reshape(kv, g, dh)
    scores = jnp.einsum(
        "kgd,skd->kgs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    kmask = lax.broadcasted_iota(jnp.int32, (1, 1, s), 2) < klen
    scores = jnp.where(kmask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("kgs,skd->kgd", probs, v.astype(jnp.float32))
    o_ref[0] = out.reshape(h, dh).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softmax_scale", "interpret")
)
def decode_attention_pallas(
    q: jnp.ndarray,        # (B, H, dh)
    k_new: jnp.ndarray,    # (B, KV, dh)
    v_new: jnp.ndarray,    # (B, KV, dh)
    k_cache: jnp.ndarray,  # (B, S, KV, dh)
    v_cache: jnp.ndarray,  # (B, S, KV, dh)
    pos: jnp.ndarray,      # (B,) int32
    kv_len: jnp.ndarray,   # (B,) int32
    *,
    softmax_scale: float | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    scale = softmax_scale or dh**-0.5
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda bb, p_, l_: (bb, 0, 0)),
            pl.BlockSpec((1, kv, dh), lambda bb, p_, l_: (bb, 0, 0)),
            pl.BlockSpec((1, kv, dh), lambda bb, p_, l_: (bb, 0, 0)),
            pl.BlockSpec((1, s, kv, dh), lambda bb, p_, l_: (bb, 0, 0, 0)),
            pl.BlockSpec((1, s, kv, dh), lambda bb, p_, l_: (bb, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda bb, p_, l_: (bb, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_decode_attention_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(
        pos.astype(jnp.int32), kv_len.astype(jnp.int32),
        q, k_new, v_new, k_cache, v_cache,
    )
