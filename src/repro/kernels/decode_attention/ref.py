"""Pure-jnp oracle: today's unfused decode-attention path, verbatim.

This is the exact op sequence ``repro.models.transformer._self_attn``
runs on the decode (S==1) path: functionally update the K/V slab at each
sequence's write position (``.at[idx, pos].set`` — the HBM slab copy the
fused kernel removes), then dense attention over the updated slab with
the ``kv_len`` prefix mask.  The kernel is gated on being bitwise equal
to this function; this function stays bitwise equal to the model path by
calling the same :func:`repro.models.layers.attention_dense`.
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,       # (B, 1, H, dh) — the one decode query
    k_new: jnp.ndarray,   # (B, KV, dh) — this step's K row (cache dtype)
    v_new: jnp.ndarray,   # (B, KV, dh)
    k_cache: jnp.ndarray, # (B, S, KV, dh) — the cache slab (pre-update)
    v_cache: jnp.ndarray, # (B, S, KV, dh)
    *,
    pos: jnp.ndarray,     # (B,) int32 per-sequence write position
    kv_len: jnp.ndarray,  # (B,) or (B,1) valid KV count after the write
    softmax_scale: float | None = None,
    interpret: bool | None = None,  # accepted for signature parity
) -> jnp.ndarray:
    from repro.models.layers import attention_dense

    b = q.shape[0]
    idx = jnp.arange(b)
    ck = k_cache.at[idx, pos].set(k_new)
    cv = v_cache.at[idx, pos].set(v_new)
    return attention_dense(
        q, ck, cv, causal=False,
        kv_len=jnp.asarray(kv_len).reshape(b, 1),
        softmax_scale=softmax_scale,
    )
