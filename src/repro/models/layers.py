"""Shared transformer layers: norms, RoPE, GQA attention, gated MLP.

All functions are pure; parameters are declared as ParamSpec layouts by
the companion ``*_layout`` functions, so models compose layouts and apply
functions in parallel trees.

Attention comes in three interchangeable implementations (config
``attn_impl``):

* ``dense`` — full score matrix; smoke tests and short sequences.
* ``chunked`` — pure-jnp streaming attention (online softmax over KV
  chunks), the ref oracle for the Pallas kernel and the lowering used by
  the CPU dry-run; memory O(chunk²) instead of O(S²).  The KV chunk axis
  is a bounded stream with carried (m, l, o) state — the paper's construct
  applied to the sequence dimension.
* ``pallas`` — :mod:`repro.kernels.flash_attention` (TPU target).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.params import ParamSpec

PyTree = Any

# ---------------------------------------------------------------------------
# Activation sharding constraints (GSPMD guard rails)
#
# Without these, sharding propagation inside a layer is free to replicate
# the batch or split hidden dims arbitrarily (observed: attention internals
# batch-replicated at 256 chips).  Constraints pin the canonical layout:
# batch over (pod, data), heads/ffn over model, residual d unsharded.
# ---------------------------------------------------------------------------

_BATCH = ("pod", "data")


def constrain(x, *axes):
    """maybe_constrain with ('pod','data') batch plus given tail axes."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import maybe_constrain

    return maybe_constrain(x, P(_BATCH, *axes))


def constrain_res(x):  # (B, S, d)
    return constrain(x, None, None)


def constrain_heads(x):  # (B, S, H|KV, dh)
    return constrain(x, None, "model", None)


def constrain_ffn(x):  # (B, S, f)
    return constrain(x, None, "model")


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_layout(dim: int, stacked: tuple[int, ...] = ()):
    axes = ("layers",) * len(stacked) + ("embed",)
    return {"scale": ParamSpec(stacked + (dim,), axes, init="ones", dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


def layernorm_nonparam(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * lax.rsqrt(var + eps)).astype(dtype)


def make_norm(norm: str, dim: int, stacked: tuple[int, ...] = ()):
    """Returns (layout, apply(params, x))."""
    if norm == "rmsnorm":
        return rmsnorm_layout(dim, stacked), rmsnorm
    if norm == "layernorm_nonparam":
        return {}, lambda params, x, eps=1e-5: layernorm_nonparam(x, eps)
    raise ValueError(norm)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: (..., S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core — dense and chunked (streaming) implementations
# ---------------------------------------------------------------------------


def _gqa_scores_shape(q, k):
    """q: (B,Sq,H,dh) k: (B,Sk,KV,dh) -> q grouped (B,Sq,KV,G,dh)."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    return q.reshape(b, sq, kv, h // kv, dh)


def attention_dense(
    q, k, v, *, causal: bool, q_offset=0, kv_len=None, softmax_scale=None
):
    """Full-score attention.  q:(B,Sq,H,dh) k,v:(B,Sk,KV,dh) -> (B,Sq,H,dh).

    ``q_offset``: absolute position of q[0] (decode: Sq=1, offset=pos).
    ``kv_len``: number of valid KV positions (rest masked; cache padding).
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    scale = softmax_scale or dh**-0.5
    qg = _gqa_scores_shape(q, k)  # (B,Sq,KV,G,dh)
    scores = jnp.einsum(
        "bqkgd,bskd->bqkgs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    kv_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        q_pos = jnp.arange(sq) + q_offset
        mask &= kv_pos[None, :] <= q_pos[:, None]
    scores = jnp.where(mask[None, :, None, None, :], scores, -jnp.inf)
    if kv_len is not None:
        # kv_len: scalar or (B,)/(B,1) per-sequence valid length.
        klen = jnp.asarray(kv_len).reshape(-1, 1)  # (B,1) or (1,1)
        kmask = kv_pos[None, :] < klen  # (B,S)
        scores = jnp.where(kmask[:, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # Rows that are fully masked produce NaN; scrub (decode prefix).
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bqkgs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def attention_chunked(
    q,
    k,
    v,
    *,
    causal: bool,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset=0,
    kv_len=None,
    softmax_scale=None,
    causal_skip=None,
):
    """Streaming (online-softmax) attention; the flash-attention oracle.

    Scans KV chunks as a bounded stream with carried (m, l, acc) — memory
    O(q_chunk × kv_chunk) — and vmaps over q chunks.
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    scale = softmax_scale or dh**-0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    # Pad ragged sequence lengths (e.g. 1601 vision tokens) to chunk
    # multiples; padded KV is masked via kv_len, padded Q sliced off.
    sq_pad = -(-sq // q_chunk) * q_chunk
    sk_pad = -(-sk // kv_chunk) * kv_chunk
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        kv_len = jnp.minimum(jnp.asarray(kv_len if kv_len is not None else sk), sk)
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    orig_sq, sq, sk = sq, sq_pad, sk_pad
    nq, nk = sq // q_chunk, sk // kv_chunk
    g = h // kv

    # Blocks stay in the input dtype (bf16 on TPU) — scores/stats in fp32.
    # fp32 copies of Q/K/V were the dominant HBM traffic (§Perf iter. 3).
    # Batch sharding is re-pinned on the chunked views: GSPMD loses it
    # through the pair-scan's dynamic chunk indexing when the head dims
    # are replicated (archs with heads % model != 0), replicating and
    # re-gathering the whole batch instead (§Perf iteration 4).
    qg = constrain(q.reshape(b, nq, q_chunk, kv, g, dh), None, None, None, None, None)
    kc = constrain(k.reshape(b, nk, kv_chunk, kv, dh), None, None, None, None)
    vc = constrain(v.reshape(b, nk, kv_chunk, kv, dh), None, None, None, None)

    def block_update(carry, q_blk, k_blk, v_blk, qi, kj):
        m, l, acc = carry
        s = jnp.einsum(
            "bqkgd,bskd->bqkgs", q_blk, k_blk,
            preferred_element_type=jnp.float32,
        ) * scale
        kv_pos = kj * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((q_chunk, kv_chunk), bool)
        if causal:
            q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
            mask &= kv_pos[None, :] <= q_pos[:, None]
        mask = jnp.broadcast_to(mask[None], (b, q_chunk, kv_chunk))
        if kv_len is not None:
            klen = jnp.asarray(kv_len).reshape(-1, 1)  # (B,1) or (1,1)
            mask &= (kv_pos[None, :] < klen)[:, None, :]
        mask = mask[:, :, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard -inf rows (no valid key yet)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p.astype(q_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        return m_new, l, acc

    static_offset = isinstance(q_offset, (int, np.integer)) and q_offset == 0
    auto_skip = causal and static_offset and sq == sk and kv_len is None
    # None = auto.  The skip halves attention FLOPs but its pair-scan
    # backward carries more HBM traffic on some shapes; memory-bound
    # cells may prefer it off (§Perf iteration 6).
    causal_skip = auto_skip if causal_skip is None else (causal_skip and auto_skip)
    # vma seed: carries must inherit the varying-manual-axes type when this
    # runs inside a partial-manual shard_map (the pod-axis pipeline);
    # adding a zero derived from q is a no-op elsewhere.
    vma0 = (qg.astype(jnp.float32) * 0).sum()

    if causal_skip:
        # Triangular pair-list scan: blocks strictly above the diagonal
        # are never touched — halves attention compute AND traffic
        # (§Perf iteration 3b).  Carry holds per-q-chunk (m, l, acc).
        pairs = np.asarray(
            [(qi, kj) for qi in range(nq) for kj in range(qi + 1)], np.int32
        )

        def pair_step(carry, pair):
            qi, kj = pair[0], pair[1]
            m, l, acc = carry
            q_blk = lax.dynamic_index_in_dim(qg, qi, 1, keepdims=False)
            k_blk = lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
            v_blk = lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
            sub = (
                lax.dynamic_index_in_dim(m, qi, 0, keepdims=False),
                lax.dynamic_index_in_dim(l, qi, 0, keepdims=False),
                lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False),
            )
            m_n, l_n, acc_n = block_update(sub, q_blk, k_blk, v_blk, qi, kj)
            m = lax.dynamic_update_index_in_dim(m, m_n, qi, 0)
            l = lax.dynamic_update_index_in_dim(l, l_n, qi, 0)
            acc = lax.dynamic_update_index_in_dim(acc, acc_n, qi, 0)
            return (m, l, acc), None

        m0 = jnp.full((nq, b, q_chunk, kv, g), -jnp.inf) + vma0
        l0 = jnp.zeros((nq, b, q_chunk, kv, g)) + vma0
        acc0 = jnp.zeros((nq, b, q_chunk, kv, g, dh)) + vma0
        (m, l, acc), _ = lax.scan(
            jax.checkpoint(pair_step), (m0, l0, acc0), jnp.asarray(pairs)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (nq,B,qc,KV,G,dh)
        out = jnp.moveaxis(out, 0, 1)
    else:
        def one_q_chunk(qi, q_blk):
            def kv_step(carry, inputs):
                kj, k_blk, v_blk = inputs
                return block_update(carry, q_blk, k_blk, v_blk, qi, kj), None

            m0 = jnp.full((b, q_chunk, kv, g), -jnp.inf) + vma0
            l0 = jnp.zeros((b, q_chunk, kv, g)) + vma0
            acc0 = jnp.zeros((b, q_chunk, kv, g, dh)) + vma0
            # checkpoint per KV block: backward recomputes s/p instead of
            # saving every block's probability matrix (flash rule)
            (m, l, acc), _ = lax.scan(
                jax.checkpoint(kv_step),
                (m0, l0, acc0),
                (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
            )
            return acc / jnp.maximum(l[..., None], 1e-30)

        out = jax.vmap(one_q_chunk, in_axes=(0, 1), out_axes=1)(
            jnp.arange(nq), qg
        )  # (B, nq, q_chunk, KV, G, dh)
    return out.reshape(b, sq, h, dh)[:, :orig_sq].astype(q.dtype)


def attention(q, k, v, *, impl: str = "dense", **kw):
    if impl == "dense":
        for extra in ("q_chunk", "kv_chunk", "causal_skip"):
            kw.pop(extra, None)
        return attention_dense(q, k, v, **kw)
    if impl == "chunked":
        return attention_chunked(q, k, v, **kw)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, **kw)
    raise ValueError(impl)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + qk-norm)
# ---------------------------------------------------------------------------


def attn_layout(cfg, stacked: tuple[int, ...] = (), cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ax = ("layers",) * len(stacked)
    out = {
        "wq": ParamSpec(stacked + (d, h, dh), ax + ("embed", "heads", "head_dim"), dtype=cfg.dtype),
        "wk": ParamSpec(stacked + (d, kv, dh), ax + ("embed", "kv_heads", "head_dim"), dtype=cfg.dtype),
        "wv": ParamSpec(stacked + (d, kv, dh), ax + ("embed", "kv_heads", "head_dim"), dtype=cfg.dtype),
        "wo": ParamSpec(stacked + (h, dh, d), ax + ("heads", "head_dim", "embed"), dtype=cfg.dtype),
    }
    if cfg.qkv_bias and not cross:
        out["bq"] = ParamSpec(stacked + (h, dh), ax + ("heads", "head_dim"), init="zeros", dtype=cfg.dtype)
        out["bk"] = ParamSpec(stacked + (kv, dh), ax + ("kv_heads", "head_dim"), init="zeros", dtype=cfg.dtype)
        out["bv"] = ParamSpec(stacked + (kv, dh), ax + ("kv_heads", "head_dim"), init="zeros", dtype=cfg.dtype)
    if cfg.qk_norm:
        out["q_norm"] = ParamSpec(stacked + (dh,), ax + ("head_dim",), init="ones", dtype=jnp.float32)
        out["k_norm"] = ParamSpec(stacked + (dh,), ax + ("head_dim",), init="ones", dtype=jnp.float32)
    return out


def _maybe_qk_norm(params, q, k, eps):
    if "q_norm" in params:
        q = rmsnorm({"scale": params["q_norm"]}, q, eps)
        k = rmsnorm({"scale": params["k_norm"]}, k, eps)
    return q, k


def attn_project_qkv(params, x, cfg, positions):
    """x: (B,S,d) -> q,k,v with rope + optional bias/qk-norm."""
    q = constrain_heads(jnp.einsum("bsd,dhk->bshk", x, params["wq"]))
    k = constrain_heads(jnp.einsum("bsd,dhk->bshk", x, params["wk"]))
    v = constrain_heads(jnp.einsum("bsd,dhk->bshk", x, params["wv"]))
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q, k = _maybe_qk_norm(params, q, k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(params, ctx):
    return constrain_res(
        jnp.einsum("bshk,hkd->bsd", constrain_heads(ctx), params["wo"])
    )


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_layout(cfg, d_ff: int | None = None, stacked: tuple[int, ...] = ()):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ax = ("layers",) * len(stacked)
    return {
        "w_gate": ParamSpec(stacked + (d, f), ax + ("embed", "ffn"), dtype=cfg.dtype),
        "w_up": ParamSpec(stacked + (d, f), ax + ("embed", "ffn"), dtype=cfg.dtype),
        "w_down": ParamSpec(stacked + (f, d), ax + ("ffn", "embed"), dtype=cfg.dtype),
    }


def mlp(params, x):
    gate = constrain_ffn(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    up = constrain_ffn(jnp.einsum("bsd,df->bsf", x, params["w_up"]))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return constrain_res(jnp.einsum("bsf,fd->bsd", act, params["w_down"]))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_layout(cfg):
    # Untied: ("vocab_table", "embed_table") -> (None, "model") — input
    # gather stays local per shard (a vocab-sharded gather forces SPMD to
    # replicate the table).
    # Tied: the table doubles as the LM head, which must produce
    # vocab-sharded logits -> shard over vocab and accept one table
    # all-gather at the input gather (cheap: tied archs have small
    # vocab×d).  See EXPERIMENTS.md §Perf for the measured trade.
    axes = ("vocab", None) if cfg.tie_embeddings else ("vocab_table", "embed_table")
    return {
        "embedding": ParamSpec(
            (cfg.vocab_size, cfg.d_model), axes,
            init="embed", init_scale=0.02, dtype=cfg.dtype,
        )
    }


def head_layout(cfg):
    if cfg.tie_embeddings:
        return {}
    return {
        "w": ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dtype=cfg.dtype
        )
    }


def logits(head_params, embed_params, x, cfg):
    if cfg.tie_embeddings:
        # contraction over d (unsharded) -> logits sharded over vocab
        return constrain(
            jnp.einsum("bsd,vd->bsv", x, embed_params["embedding"]), None, "model"
        ).astype(jnp.float32)
    return constrain(
        jnp.einsum("bsd,dv->bsv", x, head_params["w"]), None, "model"
    ).astype(jnp.float32)


def embed_lookup(table, tokens):
    """Token embedding lookup (gather).

    The table is sharded over its *embedding* dim ('embed_table' ->
    'model'), never its vocab rows: a row gather from a vocab-sharded
    table forces SPMD replication (involuntary full rematerialization),
    while a gather from an embed-sharded table is fully local per shard
    and d(table) is a local scatter-add + data-axis reduce.
    """
    return table[tokens]
