"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

The chunked SSD algorithm *is* a stream computation in the paper's sense:
sequence chunks are cells, the (H, P, N) state is the value carried from
cell to cell, intra-chunk work is the per-cell footprint.  The cross-chunk
recurrence runs either as a sequential scan (the Lazy evaluation) or as an
associative scan (beyond-paper parallelization of the chain; see
EXPERIMENTS.md §Perf).

Layout per block (d_inner = expand * d_model, H = d_inner / head_dim):

    in_proj : d -> [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
    conv1d  : depthwise width-w over (x ⊕ B ⊕ C)
    A_log, D, dt_bias : (H,)
    norm    : gated RMSNorm over d_inner
    out_proj: d_inner -> d
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.params import ParamSpec
from repro.models.layers import rmsnorm


def ssm_dims(cfg: ArchConfig, ssm: SSMConfig):
    d_inner = ssm.expand * cfg.d_model
    num_heads = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.num_groups * ssm.state_dim
    proj_dim = 2 * d_inner + 2 * ssm.num_groups * ssm.state_dim + num_heads
    return d_inner, num_heads, conv_dim, proj_dim


def ssm_layout(cfg: ArchConfig, ssm: SSMConfig, stacked: tuple[int, ...] = ()):
    d_inner, num_heads, conv_dim, proj_dim = ssm_dims(cfg, ssm)
    ax = ("layers",) * len(stacked)
    return {
        "in_proj": ParamSpec(
            stacked + (cfg.d_model, proj_dim), ax + ("embed", "ffn"), dtype=cfg.dtype
        ),
        "conv_w": ParamSpec(
            stacked + (ssm.conv_width, conv_dim), ax + ("conv", "ffn"), dtype=cfg.dtype
        ),
        "conv_b": ParamSpec(
            stacked + (conv_dim,), ax + ("ffn",), init="zeros", dtype=cfg.dtype
        ),
        "A_log": ParamSpec(stacked + (num_heads,), ax + ("heads",), init="zeros", dtype=jnp.float32),
        "D": ParamSpec(stacked + (num_heads,), ax + ("heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec(stacked + (num_heads,), ax + ("heads",), init="zeros", dtype=jnp.float32),
        "norm_scale": ParamSpec(stacked + (d_inner,), ax + ("ffn",), init="ones", dtype=jnp.float32),
        "out_proj": ParamSpec(
            stacked + (d_inner, cfg.d_model), ax + ("ffn", "embed"), dtype=cfg.dtype
        ),
    }


def _split_proj(proj, cfg, ssm):
    d_inner, num_heads, _, _ = ssm_dims(cfg, ssm)
    gn = ssm.num_groups * ssm.state_dim
    z, xs, bb, cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn], axis=-1
    )
    return z, xs, bb, cc, dt


def ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: (B,S,H,P) values; dt: (B,S,H) step sizes (post-softplus);
    a: (H,) negative decay rates; b_mat/c_mat: (B,S,G,N); d_skip: (H,).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g  # heads per group

    f32 = jnp.float32
    # Chunk-major layout for the scan: (nc, B, Q, ...).
    xc = jnp.moveaxis(x.reshape(bsz, nc, chunk, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(bsz, nc, chunk, h), 1, 0).astype(f32)
    bc = jnp.moveaxis(b_mat.reshape(bsz, nc, chunk, g, n), 1, 0).astype(f32)
    cc = jnp.moveaxis(c_mat.reshape(bsz, nc, chunk, g, n), 1, 0).astype(f32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    if initial_state is None:
        # vma seed (see layers.attention_chunked): inherit varying axes
        s0 = jnp.zeros((bsz, h, n, p), f32) + (x.astype(f32) * 0).sum()
    else:
        s0 = initial_state.astype(f32)

    def chunk_cell(carry, inp):
        """One stream cell: per-chunk SSD with the (H,N,P) state flowing."""
        x_b, dt_b, b_b, c_b = inp  # (B,Q,H,P) (B,Q,H) (B,Q,G,N) ×2
        x_f = x_b.astype(f32)
        da = dt_b * a  # (B,Q,H), negative
        cum = jnp.cumsum(da, axis=1)
        total = cum[:, -1, :]  # (B,H)

        # Intra-chunk: L[i,j] = exp(cum_i - cum_j) for j<=i (Q,Q per head).
        decay = jnp.where(
            tri[None, :, :, None],
            jnp.exp(cum[:, :, None, :] - cum[:, None, :, :]),
            0.0,
        )  # (B,Q,Q,H)
        cb = jnp.einsum("bign,bjgn->bijg", c_b, b_b)  # (B,Q,Q,G)
        cb = jnp.repeat(cb, hg, axis=-1)  # (B,Q,Q,H)
        w = cb * decay * dt_b[:, None, :, :]
        y_chunk = jnp.einsum("bijh,bjhp->bihp", w, x_f)

        # Inter-chunk: contribution of the carried state.
        ch = jnp.repeat(c_b, hg, axis=2).reshape(bsz, chunk, h, n)
        y_chunk = y_chunk + jnp.einsum(
            "bqhn,bhnp,bqh->bqhp", ch, carry, jnp.exp(cum)
        )

        # State update (the future handed to the next cell).
        state_decay = jnp.exp(total[:, None, :] - cum) * dt_b  # (B,Q,H)
        bh = jnp.repeat(b_b, hg, axis=2).reshape(bsz, chunk, h, n)
        new_state = carry * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bqh,bqhn,bqhp->bhnp", state_decay, bh, x_f
        )
        y_chunk = y_chunk + x_f * d_skip[None, None, :, None]
        return new_state, y_chunk.astype(x.dtype)

    # checkpoint per chunk: backward recomputes the (Q,Q,H) decay/score
    # tensors instead of saving one per chunk (the SSD flash rule).
    final, ys = lax.scan(jax.checkpoint(chunk_cell), s0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final


def causal_conv1d(x, w, b, *, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C), b: (C,).

    With ``state`` (B,W-1,C): single-step decode (S may be 1); returns
    (y, new_state).  Without: full-sequence, zero history.
    """
    bsz, s, c = x.shape
    width = w.shape[0]
    if state is None:
        hist = jnp.zeros((bsz, width - 1, c), x.dtype)
    else:
        hist = state.astype(x.dtype)
    full = jnp.concatenate([hist, x], axis=1)  # (B, S+W-1, C)
    # Accumulate shifted taps (no (B,S,W,C) materialization).
    y = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(width):
        y = y + full[:, i : i + s, :].astype(jnp.float32) * w[i]
    y = y + b
    new_state = full[:, -(width - 1) :, :] if width > 1 else hist
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def ssm_block(params, x, cfg: ArchConfig, ssm: SSMConfig, *, cache=None):
    """Full Mamba-2 block.  x: (B,S,d) -> (y, new_cache).

    cache = {"conv": (B,W-1,conv_dim), "state": (B,H,N,P)} for decode.
    """
    from repro.models.layers import constrain_ffn, constrain_res

    d_inner, num_heads, conv_dim, _ = ssm_dims(cfg, ssm)
    proj = constrain_ffn(jnp.einsum("bsd,dp->bsp", x, params["in_proj"]))
    z, xs, bb, cc, dt = _split_proj(proj, cfg, ssm)

    conv_in = jnp.concatenate([xs, bb, cc], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    conv_out, new_conv = causal_conv1d(
        conv_in, params["conv_w"], params["conv_b"], state=conv_state
    )
    xs, bb, cc = jnp.split(conv_out, [d_inner, d_inner + ssm.num_groups * ssm.state_dim], axis=-1)

    bsz, s, _ = x.shape
    xh = xs.reshape(bsz, s, num_heads, ssm.head_dim)
    bm = bb.reshape(bsz, s, ssm.num_groups, ssm.state_dim)
    cm = cc.reshape(bsz, s, ssm.num_groups, ssm.state_dim)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    init_state = None if cache is None else cache["state"]
    if cache is not None and s == 1:
        # Single-token decode: closed-form state update (no chunking).
        y, final = _ssd_decode_step(xh, dt_act, a, bm, cm, params["D"], init_state)
    else:
        chunk = min(ssm.chunk_size, s)
        y, final = ssd_chunked(
            xh, dt_act, a, bm, cm, params["D"], chunk=chunk,
            initial_state=init_state,
        )

    y = y.reshape(bsz, s, d_inner)
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    y = rmsnorm(
        {"scale": params["norm_scale"]},
        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
        cfg.norm_eps,
    )
    out = constrain_res(jnp.einsum("bsi,id->bsd", y, params["out_proj"]))
    new_cache = {"conv": new_conv, "state": final}
    return out, new_cache


def _ssd_decode_step(xh, dt, a, bm, cm, d_skip, state):
    """One-token SSD update. xh: (B,1,H,P); state: (B,H,N,P)."""
    bsz, _, h, p = xh.shape
    g, n = bm.shape[2], bm.shape[3]
    hg = h // g
    f32 = jnp.float32
    x0 = xh[:, 0].astype(f32)  # (B,H,P)
    dt0 = dt[:, 0]  # (B,H)
    b0 = jnp.repeat(bm[:, 0], hg, axis=1).astype(f32)  # (B,H,N)
    c0 = jnp.repeat(cm[:, 0], hg, axis=1).astype(f32)
    decay = jnp.exp(dt0 * a)  # (B,H)
    st = state.astype(f32) * decay[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt0, b0, x0
    )
    y = jnp.einsum("bhn,bhnp->bhp", c0, st) + x0 * d_skip[None, :, None]
    return y[:, None].astype(xh.dtype), st


def init_ssm_cache(cfg: ArchConfig, ssm: SSMConfig, batch: int, dtype):
    d_inner, num_heads, conv_dim, _ = ssm_dims(cfg, ssm)
    return {
        "conv": jnp.zeros((batch, ssm.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros(
            (batch, num_heads, ssm.state_dim, ssm.head_dim), jnp.float32
        ),
    }
