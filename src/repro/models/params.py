"""Declarative parameter layouts.

A model declares its parameters as a pytree of :class:`ParamSpec` (shape +
logical axis names + init).  From one layout we derive:

* real parameters (``init_params``) for smoke tests / examples,
* ``jax.ShapeDtypeStruct`` stand-ins (``abstract_params``) for the
  multi-pod dry-run — no allocation at 398 B scale,
* ``PartitionSpec``/``NamedSharding`` trees (``param_pspecs``) via the
  logical-axis rules in :mod:`repro.parallel.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed
    init_scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"shape {self.shape} vs logical_axes {self.logical_axes}"
            )


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(key, spec: ParamSpec) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        scale = spec.init_scale
    elif spec.init == "fan_in":
        # fan-in = product of all axes except the last
        fan_in = max(1, int(np.prod(spec.shape[:-1])) // max(1, spec.shape[0] if len(spec.shape) > 2 else 1))
        # For stacked layers (leading 'layers'/'stage' axis) fan-in excludes it.
        non_stack = [
            d
            for d, ax in zip(spec.shape, spec.logical_axes)
            if ax not in ("layers", "stage", "experts")
        ]
        fan_in = max(1, int(np.prod(non_stack[:-1]))) if len(non_stack) > 1 else 1
        scale = spec.init_scale / np.sqrt(fan_in)
    else:  # normal
        scale = spec.init_scale
    x = jax.random.normal(key, spec.shape, jnp.float32) * scale
    return x.astype(spec.dtype)


def init_params(rng: jax.Array, layout: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(layout, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(k, s) for k, s in zip(keys, leaves)]
    )


def abstract_params(layout: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        layout,
        is_leaf=is_spec,
    )


def param_logical_axes(layout: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.logical_axes, layout, is_leaf=is_spec)


def param_count(layout: PyTree) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(layout, is_leaf=is_spec)
    )


def cast_layout(layout: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda s: dataclasses.replace(s, dtype=dtype), layout, is_leaf=is_spec
    )
