"""Mixture-of-Experts MLP with sort-based dispatch (expert parallel).

Dispatch is one-hot-free: assignments are ranked within their expert via a
single argsort (MegaBlocks-style grouping), scattered into a capacity-
bounded (E, C, d) buffer, processed with batched expert GEMMs, and
combined with a scatter-add.  Experts shard over the ``model`` mesh axis
(EP folded onto TP); token activations stay sharded over ``data``, so
GSPMD inserts the dispatch/combine exchanges.

The MoE dispatch chain (route → exchange → expert GEMM → combine) is
itself a stream of dependent cells; under the pipeline evaluator the
exchange of chunk b overlaps the GEMM of chunk b-1 (see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, MoEConfig
from repro.models.params import ParamSpec


def moe_layout(cfg: ArchConfig, moe: MoEConfig, stacked: tuple[int, ...] = ()):
    d, e, f = cfg.d_model, moe.num_experts, moe.d_ff_expert
    ax = ("layers",) * len(stacked)
    out = {
        "router": ParamSpec(
            stacked + (d, e), ax + ("embed", None), dtype=jnp.float32
        ),
        "w_gate": ParamSpec(
            stacked + (e, d, f), ax + ("experts", "mlp_in", None), dtype=cfg.dtype
        ),
        "w_up": ParamSpec(
            stacked + (e, d, f), ax + ("experts", "mlp_in", None), dtype=cfg.dtype
        ),
        "w_down": ParamSpec(
            stacked + (e, f, d), ax + ("experts", None, "mlp_in"), dtype=cfg.dtype
        ),
    }
    if moe.num_shared_experts:
        fs = f * moe.num_shared_experts
        out["shared"] = {
            "w_gate": ParamSpec(stacked + (d, fs), ax + ("embed", "ffn"), dtype=cfg.dtype),
            "w_up": ParamSpec(stacked + (d, fs), ax + ("embed", "ffn"), dtype=cfg.dtype),
            "w_down": ParamSpec(stacked + (fs, d), ax + ("ffn", "embed"), dtype=cfg.dtype),
        }
    return out


def _data_shards(t: int) -> int:
    """Number of batch shards the dispatch is blocked by.

    The dispatch scatter/gather is *blocked per data shard* (leading vmap
    dim sharded over (pod, data)) so every scatter stays shard-local —
    GSPMD partitions a batched scatter along its batch dim for free,
    whereas a flat cross-shard scatter triggers pathological resharding
    (observed: moonshot train_4k failed HLO verification at 256 chips).
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    shards = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            shards *= mesh.shape[ax]
    while shards > 1 and t % shards != 0:
        shards //= 2
    return max(shards, 1)


def moe_apply(params, x, moe: MoEConfig, *, capacity: int | None = None):
    """x: (B, S, d) -> (y, aux).  Token-drop routing with capacity bound."""
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    xf = x.reshape(t, d)

    # --- route (fp32) -----------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, k)  # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # --- aux losses ---------------------------------------------------------
    # load-balance (Switch): E * sum_e fraction_e * prob_e
    assign_onehot_mean = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t * k)
    )
    prob_mean = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(assign_onehot_mean * prob_mean)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- dispatch: per-data-shard blocked, sort-based ranking ---------------
    ds = _data_shards(t)
    tl = t // ds  # tokens per shard block
    if capacity is None:
        capacity = int(np.ceil(tl * k / e * moe.capacity_factor))
        capacity = max(8, -(-capacity // 8) * 8)

    from repro.parallel.sharding import maybe_constrain

    def dispatch_block(xb, eids, gates):
        """xb: (tl, d); eids: (tl, k); gates: (tl, k) -> (y (tl,d))."""
        flat_e = eids.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(tl), k)
        flat_gate = gates.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e))
        rank_sorted = jnp.arange(tl * k) - starts[sorted_e]
        rank = (
            jnp.zeros((tl * k,), jnp.int32)
            .at[order]
            .set(rank_sorted.astype(jnp.int32))
        )
        keep = rank < capacity
        dest = jnp.where(keep, flat_e * capacity + rank, e * capacity)
        buf = jnp.zeros((e * capacity, d), x.dtype)
        buf = buf.at[dest].set(xb[flat_tok], mode="drop")
        return buf.reshape(e, capacity, d), (dest, flat_tok, flat_gate, keep)

    xb = xf.reshape(ds, tl, d)
    eb = expert_ids.reshape(ds, tl, k)
    gb = gate_vals.reshape(ds, tl, k)
    buf, meta = jax.vmap(dispatch_block)(xb, eb, gb)
    # buf: (DS, E, C, d) — batch shards over (pod,data), experts over model.
    buf = maybe_constrain(buf, P(("pod", "data"), "model", None, None))

    # --- expert GEMMs (SwiGLU), expert-parallel over `model` -----------------
    gate = jnp.einsum("xecd,edf->xecf", buf, params["w_gate"])
    up = jnp.einsum("xecd,edf->xecf", buf, params["w_up"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out_buf = jnp.einsum("xecf,efd->xecd", act, params["w_down"])
    out_buf = maybe_constrain(
        out_buf, P(("pod", "data"), "model", None, None)
    )

    # --- combine (shard-local gather + scatter-add) ---------------------------
    def combine_block(ob, meta):
        dest, flat_tok, flat_gate, keep = meta
        flat = ob.reshape(e * capacity, d)
        contrib = flat[jnp.minimum(dest, e * capacity - 1)]
        contrib = jnp.where(keep[:, None], contrib, 0) * flat_gate[
            :, None
        ].astype(x.dtype)
        return jnp.zeros((tl, d), x.dtype).at[flat_tok].add(contrib)

    y = jax.vmap(combine_block)(out_buf, meta).reshape(t, d)

    # --- shared experts --------------------------------------------------------
    if "shared" in params:
        sh = params["shared"]
        g = jnp.einsum("td,df->tf", xf, sh["w_gate"])
        u = jnp.einsum("td,df->tf", xf, sh["w_up"])
        y = y + jnp.einsum(
            "tf,fd->td",
            jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
            sh["w_down"],
        )

    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_drop_fraction": 1.0 - jnp.mean(meta[3].astype(jnp.float32)),
    }
    return y.reshape(b, s, d), aux
