"""Composable decoder: dense / MoE / hybrid (Mamba interleave) / VLM cross-
attention / audio backbone — one implementation, driven by ArchConfig.

Layers are grouped into a repeating *period* = lcm(block pattern, MoE
interval, cross-attn interval); parameters are stacked over
``num_layers / period`` groups and the stack is scanned — compile time is
O(period), not O(num_layers), which is what makes the 100-layer configs
lowerable.

Step kinds:
  * ``forward``      — logits for full sequences (train / smoke).
  * ``prefill``      — forward + materialized KV/SSD caches + last logits.
  * ``decode_step``  — one token per sequence against preallocated caches.
  * decode *cells*   — the same decode math split into ``num_cells``
    contiguous layer-group pipeline cells (``split_decode_cells`` /
    ``make_decode_cell`` / ``make_decode_emit``): layer params ride the
    Stream's read-only ``const_state``, each cell's KV/SSD cache shard
    is its mutable state (updated by row-level scatters only); the
    serving engine runs them under ``Stream.feedback`` so the sampled
    token re-enters as the next item.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.kernels import get_impl, resolve_mode
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.params import ParamSpec

PyTree = Any


# ---------------------------------------------------------------------------
# Block plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    mixer: str  # "attn" | "mamba" | "cross_attn"
    ffn: str    # "dense" | "moe" | "none"


def effective_period(cfg: ArchConfig) -> int:
    period = cfg.pattern_period
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.every_k_layers)
    if cfg.cross_attn_every > 0:
        period = math.lcm(period, cfg.cross_attn_every)
    return period


def block_plans(cfg: ArchConfig) -> list[BlockPlan]:
    period = effective_period(cfg)
    plans = []
    for i in range(period):
        mixer = cfg.block_pattern[i % cfg.pattern_period]
        if (
            cfg.cross_attn_every > 0
            and i % cfg.cross_attn_every == cfg.cross_attn_every - 1
        ):
            mixer = "cross_attn"
        if cfg.d_ff == 0 and cfg.moe is None:
            ffn = "none"
        elif cfg.moe is not None and (
            i % cfg.moe.every_k_layers == cfg.moe.every_k_layers - 1
        ):
            ffn = "moe"
        else:
            ffn = "dense"
        plans.append(BlockPlan(mixer, ffn))
    return plans


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------


def model_layout(cfg: ArchConfig) -> PyTree:
    period = effective_period(cfg)
    if cfg.num_layers % period != 0:
        raise ValueError(f"{cfg.num_layers=} not divisible by period {period}")
    groups = cfg.num_layers // period
    stacked = (groups,)
    plans = block_plans(cfg)

    blocks: dict[str, PyTree] = {}
    for i, plan in enumerate(plans):
        blk: dict[str, PyTree] = {}
        norm_layout, _ = L.make_norm(cfg.norm, cfg.d_model, stacked)
        blk["norm_mixer"] = norm_layout
        if plan.mixer in ("attn", "cross_attn"):
            blk["attn"] = L.attn_layout(cfg, stacked, cross=plan.mixer == "cross_attn")
            if plan.mixer == "cross_attn":
                blk["xattn_gate"] = {
                    "gate": ParamSpec(stacked + (1,), ("layers", None), init="zeros", dtype=jnp.float32)
                }
        else:
            blk["mamba"] = S.ssm_layout(cfg, cfg.ssm, stacked)
        if plan.ffn != "none":
            norm2, _ = L.make_norm(cfg.norm, cfg.d_model, stacked)
            blk["norm_ffn"] = norm2
            if plan.ffn == "moe":
                blk["moe"] = M.moe_layout(cfg, cfg.moe, stacked)
            else:
                blk["mlp"] = L.mlp_layout(cfg, stacked=stacked)
        blocks[f"block{i}"] = blk

    final_norm, _ = L.make_norm(cfg.norm, cfg.d_model, ())
    return {
        "embed": L.embed_layout(cfg),
        "blocks": blocks,
        "final_norm": final_norm,
        "head": L.head_layout(cfg),
    }


# ---------------------------------------------------------------------------
# Cache layout (decode)
# ---------------------------------------------------------------------------


def cache_layout(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    """Abstract cache spec: dict mirroring blocks, leaves ShapeDtypeStruct.

    Attention: K/V (groups, B, Smax, KV, dh).  Mamba: conv + state.
    Cross-attention: precomputed vision K/V (groups, B, V, KV, dh).
    """
    groups = cfg.num_layers // effective_period(cfg)
    plans = block_plans(cfg)
    caches: dict[str, PyTree] = {}
    for i, plan in enumerate(plans):
        if plan.mixer == "attn":
            shape = (groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            caches[f"block{i}"] = {
                "k": jax.ShapeDtypeStruct(shape, cfg.dtype),
                "v": jax.ShapeDtypeStruct(shape, cfg.dtype),
            }
        elif plan.mixer == "cross_attn":
            shape = (groups, batch, cfg.vision_tokens, cfg.num_kv_heads, cfg.head_dim)
            caches[f"block{i}"] = {
                "k": jax.ShapeDtypeStruct(shape, cfg.dtype),
                "v": jax.ShapeDtypeStruct(shape, cfg.dtype),
            }
        if plan.mixer == "mamba":
            d_inner, num_heads, conv_dim, _ = S.ssm_dims(cfg, cfg.ssm)
            caches[f"block{i}"] = {
                "conv": jax.ShapeDtypeStruct(
                    (groups, batch, cfg.ssm.conv_width - 1, conv_dim), cfg.dtype
                ),
                "state": jax.ShapeDtypeStruct(
                    (groups, batch, num_heads, cfg.ssm.state_dim, cfg.ssm.head_dim),
                    jnp.float32,
                ),
            }
    return caches


def cache_logical_axes(cfg: ArchConfig) -> PyTree:
    """Logical axes per cache leaf (for sharding rules)."""
    plans = block_plans(cfg)
    axes: dict[str, PyTree] = {}
    for i, plan in enumerate(plans):
        if plan.mixer == "attn":
            ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
            axes[f"block{i}"] = {"k": ax, "v": ax}
        elif plan.mixer == "cross_attn":
            ax = ("layers", "batch", None, "kv_heads", "head_dim")
            axes[f"block{i}"] = {"k": ax, "v": ax}
        if plan.mixer == "mamba":
            axes[f"block{i}"] = {
                "conv": ("layers", "batch", None, "ffn"),
                "state": ("layers", "batch", "heads", "state", None),
            }
    return axes


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_layout(cfg, batch, max_len)
    )


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


# Block norms do NOT dispatch on the kernels knob: the XLA path's bf16
# numerics at a norm -> matmul boundary are fusion-dependent (the
# f32->bf16->f32 round-trip of the norm output is elided into some
# consumers, e.g. the SSM in-projection, but not others), so a
# materialized kernel output cannot be bitwise-stable against it.  The
# standalone rmsnorm kernel stays in the registry for callers that own
# their numerics end to end; the decode hot path gets its fusion wins
# from the decode-attention and emit-epilogue kernels, whose references
# are fusion-stable (all-f32 attention math / the emit's reshape-
# separated head matmul).
def _norm(cfg, params, x):
    if cfg.norm == "rmsnorm":
        return L.rmsnorm(params, x, cfg.norm_eps)
    return L.layernorm_nonparam(x, cfg.norm_eps)


def _self_attn(
    params, x, cfg, *, positions, cache=None, cache_pos=None, kv_len=None,
    attn_impl="dense", q_chunk=512, kv_chunk=1024, causal_skip=None,
    collect_rows=False, kernels="xla",
):
    """Self-attention; with cache: decode/chunked-prefill.

    Decode (S==1): ``cache_pos`` is (B,) per-sequence write positions.
    Chunked prefill (S>1): ``cache_pos`` is a scalar chunk offset; the
    chunk is written at [pos, pos+S) and attends causally to the cache.

    ``collect_rows`` (decode only): instead of the updated K/V slabs,
    return just the written rows ``{"k": (B, KV, dh), "v": ...}`` — the
    caller scatters them into its full cache buffer at row level, so no
    slab-sized value ever rides a scan ys or a carry write-back.
    Attention still reads the functionally-updated slab (its compute
    operand), so outputs are bitwise unchanged.

    ``kernels="pallas"`` (decode only): the fused scatter+read kernel
    replaces the functional slab update — the new K/V row is substituted
    into the cache pages inside the kernel (VMEM), so no updated slab is
    ever materialized in HBM.  It replicates the dense attention math
    bitwise, so it overrides ``attn_impl`` for the S==1 step.
    """
    q, k, v = L.attn_project_qkv(params, x, cfg, positions)
    new_cache = None
    if cache is not None:
        bsz, s = x.shape[:2]
        if s == 1 and kernels == "pallas":
            rows_k = k[:, 0].astype(cache["k"].dtype)
            rows_v = v[:, 0].astype(cache["v"].dtype)
            ctx = get_impl("decode_attention", "pallas")(
                q, rows_k, rows_v, cache["k"], cache["v"],
                pos=cache_pos, kv_len=kv_len,
            )
            if collect_rows:
                new_cache = {"k": rows_k, "v": rows_v}
            else:
                idx = jnp.arange(bsz)
                new_cache = {
                    "k": cache["k"].at[idx, cache_pos].set(rows_k),
                    "v": cache["v"].at[idx, cache_pos].set(rows_v),
                }
            return L.attn_out(params, ctx), new_cache, (k, v)
        if s == 1:
            idx = jnp.arange(bsz)
            ck = cache["k"].at[idx, cache_pos].set(k[:, 0])
            cv = cache["v"].at[idx, cache_pos].set(v[:, 0])
            causal, q_offset = False, 0
        else:  # chunked prefill: scalar offset
            zero = jnp.zeros((), cache_pos.dtype if hasattr(cache_pos, "dtype") else jnp.int32)
            start = (zero, cache_pos, zero, zero)
            ck = lax.dynamic_update_slice(cache["k"], k, start)
            cv = lax.dynamic_update_slice(cache["v"], v, start)
            causal, q_offset = True, cache_pos
        if collect_rows:
            if s != 1:
                raise ValueError("collect_rows is a decode-path (S==1) mode")
            new_cache = {
                "k": k[:, 0].astype(cache["k"].dtype),
                "v": v[:, 0].astype(cache["v"].dtype),
            }
        else:
            new_cache = {"k": ck, "v": cv}
        ctx = L.attention(
            q, ck, cv, impl=attn_impl, causal=causal, q_offset=q_offset,
            kv_len=kv_len, q_chunk=q_chunk, kv_chunk=kv_chunk,
            causal_skip=causal_skip,
        )
    else:
        ctx = L.attention(
            q, k, v, impl=attn_impl, causal=True,
            q_chunk=q_chunk, kv_chunk=kv_chunk, causal_skip=causal_skip,
        )
    return L.attn_out(params, ctx), new_cache, (k, v)


def _cross_attn(params, gate, x, cfg, *, vision_kv=None, vision_embeds=None,
                attn_impl="dense", q_chunk=512, kv_chunk=1024):
    """Cross-attention to vision tokens (gated, llama-3.2 style)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "q_norm" in params:
        q = L.rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
    if vision_kv is not None:
        k, v = vision_kv["k"], vision_kv["v"]
    else:
        k = jnp.einsum("bvd,dhk->bvhk", vision_embeds, params["wk"])
        v = jnp.einsum("bvd,dhk->bvhk", vision_embeds, params["wv"])
        if "k_norm" in params:
            k = L.rmsnorm({"scale": params["k_norm"]}, k, cfg.norm_eps)
    ctx = L.attention(
        q, k, v, impl=attn_impl, causal=False, q_chunk=q_chunk, kv_chunk=kv_chunk
    )
    out = L.attn_out(params, ctx)
    return jnp.tanh(gate["gate"]).astype(out.dtype) * out, {"k": k, "v": v}


def _apply_group(
    group_params,
    x,
    cfg,
    plans,
    *,
    positions,
    group_cache=None,
    cache_pos=None,
    kv_len=None,
    vision_embeds=None,
    collect_kv=False,
    attn_impl="dense",
    q_chunk=512,
    kv_chunk=1024,
    causal_skip=None,
    cache_rows=False,
    kernels="xla",
):
    """Apply one period group.  Returns (x, new_group_cache, aux_losses).

    ``cache_rows`` (decode only): attention blocks return just the K/V
    rows written this step (see ``_self_attn(collect_rows=True)``) and
    cross-attention blocks return nothing (their vision K/V never
    changes during decode); SSM blocks return their per-sequence state
    as usual — it is row-sized already.  The caller owns the row-level
    scatter into its full cache.
    """
    new_cache: dict[str, PyTree] = {}
    aux = {"moe_lb_loss": 0.0, "moe_z_loss": 0.0, "moe_drop_fraction": 0.0}
    num_moe = 0
    for i, plan in enumerate(plans):
        blk = group_params[f"block{i}"]
        h = _norm(cfg, blk.get("norm_mixer"), x)
        if plan.mixer == "attn":
            cache_i = None if group_cache is None else group_cache.get(f"block{i}")
            out, c_new, kv = _self_attn(
                blk["attn"], h, cfg,
                positions=positions, cache=cache_i, cache_pos=cache_pos,
                kv_len=kv_len, attn_impl=attn_impl,
                q_chunk=q_chunk, kv_chunk=kv_chunk, causal_skip=causal_skip,
                collect_rows=cache_rows, kernels=kernels,
            )
            if c_new is not None:
                new_cache[f"block{i}"] = c_new
            elif collect_kv:
                new_cache[f"block{i}"] = {"k": kv[0], "v": kv[1]}
        elif plan.mixer == "cross_attn":
            # Fresh vision embeds (prefill) take priority over cached K/V.
            vkv = None
            if vision_embeds is None and group_cache is not None:
                vkv = group_cache.get(f"block{i}")
            out, vkv_new = _cross_attn(
                blk["attn"], blk["xattn_gate"], h, cfg,
                vision_kv=vkv, vision_embeds=vision_embeds,
                attn_impl=attn_impl, q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            if (collect_kv or group_cache is not None) and not cache_rows:
                new_cache[f"block{i}"] = vkv_new
        else:  # mamba
            cache_i = None if group_cache is None else group_cache.get(f"block{i}")
            out, c_new = S.ssm_block(blk["mamba"], h, cfg, cfg.ssm, cache=cache_i)
            if group_cache is not None or collect_kv:
                new_cache[f"block{i}"] = c_new
        x = L.constrain_res(x + out)

        if plan.ffn != "none":
            h = _norm(cfg, blk.get("norm_ffn"), x)
            if plan.ffn == "moe":
                out, moe_aux = M.moe_apply(blk["moe"], h, cfg.moe)
                for key in ("moe_lb_loss", "moe_z_loss", "moe_drop_fraction"):
                    aux[key] = aux[key] + moe_aux[key]
                num_moe += 1
            else:
                out = L.mlp(blk["mlp"], h)
            x = L.constrain_res(x + out)
    if num_moe:
        aux = {k: v / num_moe for k, v in aux.items()}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------


def _embed_input(params, cfg, tokens=None, embeds=None):
    if cfg.embeds_input:
        assert embeds is not None, "stubbed-frontend arch takes embeddings"
        return embeds.astype(cfg.dtype)
    return L.embed_lookup(params["embed"]["embedding"], tokens)


def forward(
    params,
    cfg: ArchConfig,
    *,
    tokens=None,
    embeds=None,
    vision_embeds=None,
    collect_kv=False,
    cache_pad_to=None,
    attn_impl="dense",
    q_chunk=512,
    kv_chunk=1024,
    causal_skip=None,
    remat=True,
    unroll=1,
):
    """Full-sequence forward.  Returns (logits, caches|None, aux)."""
    plans = block_plans(cfg)
    x = _embed_input(params, cfg, tokens, embeds)
    bsz, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    def group_fn(x, group_params):
        x, kv, aux = _apply_group(
            group_params, x, cfg, plans,
            positions=positions, vision_embeds=vision_embeds,
            collect_kv=collect_kv, attn_impl=attn_impl,
            q_chunk=q_chunk, kv_chunk=kv_chunk, causal_skip=causal_skip,
        )
        return x, (kv, aux)

    if remat:
        group_fn = jax.checkpoint(group_fn)
    x, (kvs, auxs) = lax.scan(group_fn, x, params["blocks"], unroll=unroll)
    aux = jax.tree.map(lambda a: jnp.mean(a), auxs)
    x = _norm(cfg, params.get("final_norm"), x)
    lg = L.logits(params.get("head"), params["embed"], x, cfg)

    caches = None
    if collect_kv:
        caches = kvs
        if cache_pad_to is not None:
            caches = jax.tree.map(
                partial(_pad_cache_seq, plans=plans, pad_to=cache_pad_to),
                caches,
            )
    return lg, caches, aux


def _pad_cache_seq(x, *, plans, pad_to):
    # pads K/V (groups,B,S,KV,dh) to (groups,B,pad_to,KV,dh); leaves others
    if x.ndim == 5 and x.shape[2] < pad_to:
        pad = [(0, 0)] * 5
        pad[2] = (0, pad_to - x.shape[2])
        return jnp.pad(x, pad)
    return x


def _emit_logits(params, cfg: ArchConfig, x, kernels: str = "xla"):
    """Final-norm -> logits for one decode position: (B, 1, d) -> (B, V).

    Under ``kernels="pallas"`` the two ops run as one fused epilogue
    (norm recomputed per vocab tile in VMEM — see
    repro.kernels.emit_norm_logits); bitwise equal to the XLA path.
    """
    if kernels == "pallas":
        w = (
            params["embed"]["embedding"]
            if cfg.tie_embeddings
            else params["head"]["w"]
        )
        fn = params.get("final_norm")
        return get_impl("emit_norm_logits", "pallas")(
            x, w, norm=cfg.norm,
            scale=fn["scale"] if cfg.norm == "rmsnorm" else None,
            eps=cfg.norm_eps, tied=cfg.tie_embeddings,
        )
    xn = _norm(cfg, params.get("final_norm"), x)
    return L.logits(params.get("head"), params["embed"], xn, cfg)[:, 0, :]


def decode_step(
    params,
    caches,
    cfg: ArchConfig,
    *,
    tokens=None,
    embeds=None,
    lengths=None,
    attn_impl="dense",
    kv_chunk=1024,
    unroll=1,
    kernels=None,
):
    """One-token step.  tokens: (B,) int32 (or embeds (B,1,d)); lengths:
    (B,) current context length per sequence (cache write position).
    Returns (logits (B,V), new_caches).

    ``kernels`` (None inherits ``cfg.kernels``) selects the per-op
    implementations (see repro.kernels): ``"pallas"`` runs the fused
    decode-attention and emit-epilogue kernels (bitwise equal to the
    XLA path; interpret-emulated off-TPU)."""
    plans = block_plans(cfg)
    mode = resolve_mode(cfg.kernels if kernels is None else kernels)
    if cfg.embeds_input:
        x = embeds.astype(cfg.dtype)
        bsz = x.shape[0]
    else:
        x = L.embed_lookup(params["embed"]["embedding"], tokens)[:, None, :]
        bsz = tokens.shape[0]
    if lengths is None:
        lengths = jnp.zeros((bsz,), jnp.int32)
    positions = lengths[:, None]
    kv_len = (lengths + 1)[:, None]  # (B,1) valid kv after the write

    def group_fn(x, scan_in):
        group_params, group_cache = scan_in
        x, new_cache, aux = _apply_group(
            group_params, x, cfg, plans,
            positions=positions, group_cache=group_cache,
            cache_pos=lengths, kv_len=kv_len,
            attn_impl=attn_impl, kv_chunk=kv_chunk, q_chunk=1,
            kernels=mode,
        )
        return x, new_cache

    x, new_caches = lax.scan(group_fn, x, (params["blocks"], caches), unroll=unroll)
    return _emit_logits(params, cfg, x, mode), new_caches


def _cache_seq_len(caches):
    for blk in caches.values():
        if "k" in blk:
            return blk["k"].shape[2]
    return None


def prefill_step(
    params,
    caches,
    cfg: ArchConfig,
    *,
    tokens=None,
    embeds=None,
    pos=0,
    vision_embeds=None,
    attn_impl="chunked",
    q_chunk=512,
    kv_chunk=1024,
    unroll=1,
    logits_at: int | None = None,
    kernels=None,
):
    """Chunked streaming prefill: process a prompt chunk at offset ``pos``.

    The chunk sequence is a bounded stream whose carried value is the
    KV/SSD cache (the paper's construct on the sequence axis): chunk c's
    attention forces the cache future produced by chunk c-1.
    tokens: (B, C).  Returns (logits (B,V), new caches) — logits at the
    chunk's last position, or at index ``logits_at`` when given (static
    int or traced scalar; a ragged prompt tail padded to one masked
    chunk reads its logits at the last *real* position, and a traced
    index lets every tail length share one compiled prefill.  Pad
    queries only pollute pad rows, which the next decode's write
    position and kv_len mask retire).

    ``kernels`` (None inherits ``cfg.kernels``) is validated but prefill
    currently runs XLA in every mode: the chunk path's offset/ragged
    masking has no bitwise-stable tiled kernel, and prefill runs once
    per request, not per tick — the fused kernels target the decode
    loop (see ``decode_step`` / ``make_decode_cell``).
    """
    plans = block_plans(cfg)
    resolve_mode(cfg.kernels if kernels is None else kernels)
    if cfg.embeds_input:
        x = embeds.astype(cfg.dtype)
    else:
        x = L.embed_lookup(params["embed"]["embedding"], tokens)
    bsz, s, _ = x.shape
    static_pos = isinstance(pos, int)
    if not static_pos:
        pos = jnp.asarray(pos, jnp.int32)
    positions = (pos + jnp.arange(s))[None, :]
    # Whole-cache prefill (pos 0, chunk covers the buffer): no padding to
    # mask and a static zero offset — unlocks causal block skipping.
    full_cover = static_pos and pos == 0 and _cache_seq_len(caches) == s
    kv_len = None if full_cover else pos + s

    def group_fn(x, scan_in):
        group_params, group_cache = scan_in
        x, new_cache, _ = _apply_group(
            group_params, x, cfg, plans,
            positions=positions, group_cache=group_cache,
            cache_pos=pos, kv_len=kv_len, vision_embeds=vision_embeds,
            attn_impl=attn_impl, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return x, new_cache

    x, new_caches = lax.scan(group_fn, x, (params["blocks"], caches), unroll=unroll)
    x = _norm(cfg, params.get("final_norm"), x)
    if logits_at is None:
        xs_last = x[:, s - 1 : s, :]
    elif isinstance(logits_at, int):
        xs_last = x[:, logits_at : logits_at + 1, :]
    else:  # traced index: one compile serves every ragged-tail length
        xs_last = lax.dynamic_slice_in_dim(x, logits_at, 1, axis=1)
    lg = L.logits(params.get("head"), params["embed"], xs_last, cfg)
    return lg[:, 0, :], new_caches


# ---------------------------------------------------------------------------
# Decode as Stream cells (pipelined serving)
# ---------------------------------------------------------------------------
#
# The decode loop *is* a stream: cells = contiguous layer groups (each
# owning its params and its KV/SSD cache shard as mutable per-cell
# state), items = in-flight request microbatches.  The flowing item is a
# fixed-structure dict
#
#     {"x":   (Bm, 1, d)  hidden state (embed(tok) on entry),
#      "tok": (Bm,)       the token being decoded,
#      "pos": (Bm,)       per-slot context length (cache write position),
#      "active", "uid", "ngen", "budget": (Bm,) per-slot bookkeeping,
#      "mb":  ()          which microbatch of the batch this item is,
#      "step": ()         round-local decode step}
#
# and `make_decode_emit` closes the loop: final-norm -> logits -> sample
# -> re-embed, so the emitted item is exactly the next step's input — the
# shape `Stream.feedback` runs.  Inactive slots keep decoding with frozen
# pos/tok (identical to the sequential engine, which batches them too);
# their cache writes land at the frozen position < max_len and are
# overwritten at the next admission.
#
# Hot-path discipline (the const-state / row-scatter contract):
#   * layer params and the admission payload ride the Stream's
#     `const_state` — scan xs only, stage-sharded, never written back;
#   * the KV/SSD cache is the only mutable per-cell state, and a steady
#     decode tick touches it with row-level scatters only: attention
#     writes the one new (B, KV, dh) row per layer at its per-sequence
#     position, SSM blocks write their (row-sized) per-sequence state —
#     no microbatch slab is ever sliced out, carried through a scan ys,
#     or written back whole.


def _split_cells(tree, num_cells: int):
    def _split(leaf):
        groups = leaf.shape[0]
        if groups % num_cells != 0:
            raise ValueError(
                f"{groups} layer groups not divisible by num_cells={num_cells}"
            )
        return leaf.reshape((num_cells, groups // num_cells) + leaf.shape[1:])

    return jax.tree.map(_split, tree)


def split_decode_cells(params, caches, num_cells: int):
    """Slice params and caches into ``num_cells`` contiguous layer-group
    cells.  Leaves (groups, ...) become (num_cells, groups/num_cells,
    ...).

    Returns ``(const_state, state)`` — the Stream contract's read-only /
    mutable split:

    * ``const_state = {"blocks": ...}`` — each cell's layer-group params,
      threaded via ``Stream.through(..., const_state=...)``: delivered as
      scan xs (and stage-sharded by the Future engine, so weights are
      neither replicated per device nor gathered per tick), never
      written back.  The engine merges the per-round admission payload
      in as ``const_state["adm"]`` — it is read-only within a round too.
    * ``state = {"cache": ...}`` — the per-cell KV/SSD cache shard, the
      only thing the cells mutate.
    """
    return (
        {"blocks": _split_cells(params["blocks"], num_cells)},
        {"cache": _split_cells(caches, num_cells)},
    )


def merge_decode_caches(cell_states) -> PyTree:
    """Inverse of :func:`split_decode_cells` for the cache half."""
    return jax.tree.map(
        lambda l: l.reshape((-1,) + l.shape[2:]), cell_states["cache"]
    )


def stack_admission_payload(singles, slots, steps, mbs, num_cells: int):
    """Pack host-prefilled single-request caches into per-cell admission
    state.

    ``singles``: list of A caches from ``init_cache(cfg, 1, max_len)``
    after prefill (leaves (groups, 1, ...)).  Returns a pytree with
    leading axis ``num_cells`` holding, per cell, the slice of every
    admission's cache this cell owns plus the (slot, step, microbatch)
    the plan installs it at.  ``step == -1`` rows never fire (padding).
    """
    a_ = len(singles)

    def _cellify(*leaves):
        stacked = jnp.stack([l[:, 0] for l in leaves])  # (A, groups, ...)
        g = stacked.shape[1]
        per = g // num_cells
        stacked = stacked.reshape(
            (a_, num_cells, per) + stacked.shape[2:]
        )
        return jnp.swapaxes(stacked, 0, 1)  # (num_cells, A, gpc, ...)

    cache = jax.tree.map(lambda *ls: _cellify(*ls), *singles) if a_ else None
    meta = {
        "slot": jnp.broadcast_to(jnp.asarray(slots, jnp.int32), (num_cells, a_)),
        "step": jnp.broadcast_to(jnp.asarray(steps, jnp.int32), (num_cells, a_)),
        "mb": jnp.broadcast_to(jnp.asarray(mbs, jnp.int32), (num_cells, a_)),
    }
    return {"cache": cache, **meta} if a_ else meta


def scatter_decode_rows(cache, rows, plans, *, mb0, batch_idx, pos):
    """Row-level scatter of one decode step's cache writes.

    ``cache`` is a cell's full-batch cache shard (leaves ``(gpc, B,
    ...)``); ``rows`` the per-group rows the step produced
    (``_apply_group(cache_rows=True)`` stacked over the cell's group
    scan).  Attention K/V rows land at ``[:, batch_idx, pos]`` — one
    ``(KV, dh)`` row per sequence, an in-place scatter on the tick
    carry; SSM conv/state rows (whole per-sequence states) land as one
    contiguous ``dynamic_update_slice`` on the batch axis at ``mb0``.
    Cross-attention vision K/V never changes during decode and is left
    untouched.  Bytes written per tick: the rows themselves — the
    max_len-sized slab never moves.
    """
    out = dict(cache)
    for i, plan in enumerate(plans):
        key = f"block{i}"
        if key not in rows or key not in cache:
            continue
        if plan.mixer == "attn":
            out[key] = {
                "k": cache[key]["k"].at[:, batch_idx, pos].set(rows[key]["k"]),
                "v": cache[key]["v"].at[:, batch_idx, pos].set(rows[key]["v"]),
            }
        elif plan.mixer == "mamba":
            out[key] = jax.tree.map(
                lambda full, mb: lax.dynamic_update_slice_in_dim(
                    full, mb.astype(full.dtype), mb0, axis=1
                ),
                cache[key],
                rows[key],
            )
    return out


def make_decode_cell(
    cfg: ArchConfig,
    *,
    num_cells: int,
    microbatch: int,
    attn_impl: str = "dense",
    kv_chunk: int = 1024,
    admissions: int = 0,
    kernels: str = "xla",
):
    """One pipeline cell of the decode stream.

    ``cell_fn(const, state, item) -> (state', item')`` — the canonical
    const-state cell: ``const`` holds this cell's layer-group params
    (``const["blocks"]``, delivered by the evaluator as scan xs — no
    per-tick gather, no per-device replication) and, with ``admissions >
    0``, the in-plan admission buffer ``const["adm"]``: freshly
    prefilled whole-slot cache columns installed the moment this cell
    first sees item ``(step, mb)`` — continuous batching executed by
    the plan, not by host Python between steps.  ``state`` holds only
    the cell's cache shard, and a steady tick touches it exclusively
    through :func:`scatter_decode_rows` — the microbatch slab is read
    (the attention operand) but never sliced out/written back.

    ``kernels="pallas"`` goes one step further: the fused
    decode-attention kernel substitutes each layer's new K/V row into
    the cache pages in VMEM, so the steady tick also stops
    materializing the functionally-updated slab that the XLA path
    builds as the attention operand — row scatters become the only
    slab-touching writes left in the tick.  Outputs stay bitwise equal.
    """
    plans = block_plans(cfg)
    mode = resolve_mode(kernels)

    def cell_fn(const, state, item):
        cache = state["cache"]
        if admissions:
            adm = const["adm"]
            gates = [
                (adm["step"][a] == item["step"]) & (adm["mb"][a] == item["mb"])
                for a in range(admissions)
            ]
            any_hit = gates[0]
            for g in gates[1:]:
                any_hit = any_hit | g

            def _install_all(cache_in):
                out = cache_in
                for a in range(admissions):
                    slot = jnp.clip(adm["slot"][a], 0, None)

                    def _install(cfull, crow, _g=gates[a], _s=slot, _a=a):
                        cur = lax.dynamic_slice_in_dim(cfull, _s, 1, axis=1)
                        new = jnp.where(_g, crow[_a][:, None], cur)
                        return lax.dynamic_update_slice_in_dim(
                            cfull, new, _s, axis=1
                        )

                    out = jax.tree.map(_install, out, adm["cache"])
                return out

            # Admission ticks are rare (<= admit_per_round per round per
            # cell); everything else skips the install entirely.
            cache = lax.cond(any_hit, _install_all, lambda c: c, cache)
        mb0 = item["mb"] * microbatch
        batch_idx = mb0 + jnp.arange(microbatch)
        # Pure read: the attention operand.  The write path is the
        # row-level scatter below — nothing slab-sized rides the group
        # scan's ys or the state write-back.
        cache_mb = jax.tree.map(
            lambda c: lax.dynamic_slice_in_dim(c, mb0, microbatch, axis=1),
            cache,
        )
        lengths = item["pos"]
        positions = lengths[:, None]
        kv_len = (lengths + 1)[:, None]

        def group_fn(x, scan_in):
            group_params, group_cache = scan_in
            x, step_rows, _ = _apply_group(
                group_params, x, cfg, plans,
                positions=positions, group_cache=group_cache,
                cache_pos=lengths, kv_len=kv_len,
                attn_impl=attn_impl, kv_chunk=kv_chunk, q_chunk=1,
                cache_rows=True, kernels=mode,
            )
            return x, step_rows

        x, rows = lax.scan(group_fn, item["x"], (const["blocks"], cache_mb))
        cache = scatter_decode_rows(
            cache, rows, plans, mb0=mb0, batch_idx=batch_idx, pos=lengths
        )
        return {**state, "cache": cache}, {**item, "x": x}

    return cell_fn


def make_decode_emit(
    params,
    cfg: ArchConfig,
    *,
    sample_fn,
    eos_id: int,
    max_len: int,
    kernels: str = "xla",
):
    """The feedback emit closing the decode loop: final-norm -> logits ->
    sample -> re-embed.  ``sample_fn(logits, uid, ngen) -> (Bm,) int32``
    (the engine supplies it with temperature/seed closed over, so host
    and device sampling share one code path).  Retirement mirrors the
    sequential engine exactly: a slot freezes (pos/tok/ngen stop) once
    it has generated its budget, hit EOS, or reached the ``max_len``
    cache boundary — frozen slots keep flowing (batched decode does not
    shrink) but never advance, so no cache row at index >= max_len is
    ever written.

    ``kernels="pallas"`` fuses the norm -> logits head into the
    emit-epilogue kernel (repro.kernels.emit_norm_logits); the engine's
    conditional guard around the emit column is untouched, so the head
    matmul still only runs where the plan emits.
    """
    mode = resolve_mode(kernels)

    def emit(item):
        lg = _emit_logits(params, cfg, item["x"], mode)
        sampled = sample_fn(lg, item["uid"], item["ngen"])
        act = item["active"]
        tok = jnp.where(act, sampled, item["tok"])
        pos = jnp.where(act, item["pos"] + 1, item["pos"])
        ngen = jnp.where(act, item["ngen"] + 1, item["ngen"])
        done = (ngen >= item["budget"]) | (tok == eos_id) | (pos + 1 >= max_len)
        return {
            **item,
            "x": L.embed_lookup(params["embed"]["embedding"], tok)[:, None, :],
            "tok": tok,
            "pos": pos,
            "ngen": ngen,
            "active": act & ~done,
            "step": item["step"] + 1,
        }

    return emit
