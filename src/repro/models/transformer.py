"""Composable decoder: dense / MoE / hybrid (Mamba interleave) / VLM cross-
attention / audio backbone — one implementation, driven by ArchConfig.

Layers are grouped into a repeating *period* = lcm(block pattern, MoE
interval, cross-attn interval); parameters are stacked over
``num_layers / period`` groups and the stack is scanned — compile time is
O(period), not O(num_layers), which is what makes the 100-layer configs
lowerable.

Step kinds:
  * ``forward``      — logits for full sequences (train / smoke).
  * ``prefill``      — forward + materialized KV/SSD caches + last logits.
  * ``decode_step``  — one token per sequence against preallocated caches.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.params import ParamSpec

PyTree = Any


# ---------------------------------------------------------------------------
# Block plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    mixer: str  # "attn" | "mamba" | "cross_attn"
    ffn: str    # "dense" | "moe" | "none"


def effective_period(cfg: ArchConfig) -> int:
    period = cfg.pattern_period
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.every_k_layers)
    if cfg.cross_attn_every > 0:
        period = math.lcm(period, cfg.cross_attn_every)
    return period


def block_plans(cfg: ArchConfig) -> list[BlockPlan]:
    period = effective_period(cfg)
    plans = []
    for i in range(period):
        mixer = cfg.block_pattern[i % cfg.pattern_period]
        if (
            cfg.cross_attn_every > 0
            and i % cfg.cross_attn_every == cfg.cross_attn_every - 1
        ):
            mixer = "cross_attn"
        if cfg.d_ff == 0 and cfg.moe is None:
            ffn = "none"
        elif cfg.moe is not None and (
            i % cfg.moe.every_k_layers == cfg.moe.every_k_layers - 1
        ):
            ffn = "moe"
        else:
            ffn = "dense"
        plans.append(BlockPlan(mixer, ffn))
    return plans


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------


def model_layout(cfg: ArchConfig) -> PyTree:
    period = effective_period(cfg)
    if cfg.num_layers % period != 0:
        raise ValueError(f"{cfg.num_layers=} not divisible by period {period}")
    groups = cfg.num_layers // period
    stacked = (groups,)
    plans = block_plans(cfg)

    blocks: dict[str, PyTree] = {}
    for i, plan in enumerate(plans):
        blk: dict[str, PyTree] = {}
        norm_layout, _ = L.make_norm(cfg.norm, cfg.d_model, stacked)
        blk["norm_mixer"] = norm_layout
        if plan.mixer in ("attn", "cross_attn"):
            blk["attn"] = L.attn_layout(cfg, stacked, cross=plan.mixer == "cross_attn")
            if plan.mixer == "cross_attn":
                blk["xattn_gate"] = {
                    "gate": ParamSpec(stacked + (1,), ("layers", None), init="zeros", dtype=jnp.float32)
                }
        else:
            blk["mamba"] = S.ssm_layout(cfg, cfg.ssm, stacked)
        if plan.ffn != "none":
            norm2, _ = L.make_norm(cfg.norm, cfg.d_model, stacked)
            blk["norm_ffn"] = norm2
            if plan.ffn == "moe":
                blk["moe"] = M.moe_layout(cfg, cfg.moe, stacked)
            else:
                blk["mlp"] = L.mlp_layout(cfg, stacked=stacked)
        blocks[f"block{i}"] = blk

    final_norm, _ = L.make_norm(cfg.norm, cfg.d_model, ())
    return {
        "embed": L.embed_layout(cfg),
        "blocks": blocks,
        "final_norm": final_norm,
        "head": L.head_layout(cfg),
    }


# ---------------------------------------------------------------------------
# Cache layout (decode)
# ---------------------------------------------------------------------------


def cache_layout(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    """Abstract cache spec: dict mirroring blocks, leaves ShapeDtypeStruct.

    Attention: K/V (groups, B, Smax, KV, dh).  Mamba: conv + state.
    Cross-attention: precomputed vision K/V (groups, B, V, KV, dh).
    """
    groups = cfg.num_layers // effective_period(cfg)
    plans = block_plans(cfg)
    caches: dict[str, PyTree] = {}
    for i, plan in enumerate(plans):
        if plan.mixer == "attn":
            shape = (groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            caches[f"block{i}"] = {
                "k": jax.ShapeDtypeStruct(shape, cfg.dtype),
                "v": jax.ShapeDtypeStruct(shape, cfg.dtype),
            }
        elif plan.mixer == "cross_attn":
            shape = (groups, batch, cfg.vision_tokens, cfg.num_kv_heads, cfg.head_dim)
            caches[f"block{i}"] = {
                "k": jax.ShapeDtypeStruct(shape, cfg.dtype),
                "v": jax.ShapeDtypeStruct(shape, cfg.dtype),
            }
        if plan.mixer == "mamba":
            d_inner, num_heads, conv_dim, _ = S.ssm_dims(cfg, cfg.ssm)
            caches[f"block{i}"] = {
                "conv": jax.ShapeDtypeStruct(
                    (groups, batch, cfg.ssm.conv_width - 1, conv_dim), cfg.dtype
                ),
                "state": jax.ShapeDtypeStruct(
                    (groups, batch, num_heads, cfg.ssm.state_dim, cfg.ssm.head_dim),
                    jnp.float32,
                ),
            }
    return caches


def cache_logical_axes(cfg: ArchConfig) -> PyTree:
    """Logical axes per cache leaf (for sharding rules)."""
    plans = block_plans(cfg)
    axes: dict[str, PyTree] = {}
    for i, plan in enumerate(plans):
        if plan.mixer == "attn":
            ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
            axes[f"block{i}"] = {"k": ax, "v": ax}
        elif plan.mixer == "cross_attn":
            ax = ("layers", "batch", None, "kv_heads", "head_dim")
            axes[f"block{i}"] = {"k": ax, "v": ax}
        if plan.mixer == "mamba":
            axes[f"block{i}"] = {
                "conv": ("layers", "batch", None, "ffn"),
                "state": ("layers", "batch", "heads", "state", None),
            }
    return axes


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_layout(cfg, batch, max_len)
    )


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _norm(cfg, params, x):
    if cfg.norm == "rmsnorm":
        return L.rmsnorm(params, x, cfg.norm_eps)
    return L.layernorm_nonparam(x, cfg.norm_eps)


def _self_attn(
    params, x, cfg, *, positions, cache=None, cache_pos=None, kv_len=None,
    attn_impl="dense", q_chunk=512, kv_chunk=1024, causal_skip=None,
):
    """Self-attention; with cache: decode/chunked-prefill.

    Decode (S==1): ``cache_pos`` is (B,) per-sequence write positions.
    Chunked prefill (S>1): ``cache_pos`` is a scalar chunk offset; the
    chunk is written at [pos, pos+S) and attends causally to the cache.
    """
    q, k, v = L.attn_project_qkv(params, x, cfg, positions)
    new_cache = None
    if cache is not None:
        bsz, s = x.shape[:2]
        if s == 1:
            idx = jnp.arange(bsz)
            ck = cache["k"].at[idx, cache_pos].set(k[:, 0])
            cv = cache["v"].at[idx, cache_pos].set(v[:, 0])
            causal, q_offset = False, 0
        else:  # chunked prefill: scalar offset
            zero = jnp.zeros((), cache_pos.dtype if hasattr(cache_pos, "dtype") else jnp.int32)
            start = (zero, cache_pos, zero, zero)
            ck = lax.dynamic_update_slice(cache["k"], k, start)
            cv = lax.dynamic_update_slice(cache["v"], v, start)
            causal, q_offset = True, cache_pos
        new_cache = {"k": ck, "v": cv}
        ctx = L.attention(
            q, ck, cv, impl=attn_impl, causal=causal, q_offset=q_offset,
            kv_len=kv_len, q_chunk=q_chunk, kv_chunk=kv_chunk,
            causal_skip=causal_skip,
        )
    else:
        ctx = L.attention(
            q, k, v, impl=attn_impl, causal=True,
            q_chunk=q_chunk, kv_chunk=kv_chunk, causal_skip=causal_skip,
        )
    return L.attn_out(params, ctx), new_cache, (k, v)


def _cross_attn(params, gate, x, cfg, *, vision_kv=None, vision_embeds=None,
                attn_impl="dense", q_chunk=512, kv_chunk=1024):
    """Cross-attention to vision tokens (gated, llama-3.2 style)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "q_norm" in params:
        q = L.rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
    if vision_kv is not None:
        k, v = vision_kv["k"], vision_kv["v"]
    else:
        k = jnp.einsum("bvd,dhk->bvhk", vision_embeds, params["wk"])
        v = jnp.einsum("bvd,dhk->bvhk", vision_embeds, params["wv"])
        if "k_norm" in params:
            k = L.rmsnorm({"scale": params["k_norm"]}, k, cfg.norm_eps)
    ctx = L.attention(
        q, k, v, impl=attn_impl, causal=False, q_chunk=q_chunk, kv_chunk=kv_chunk
    )
    out = L.attn_out(params, ctx)
    return jnp.tanh(gate["gate"]).astype(out.dtype) * out, {"k": k, "v": v}


def _apply_group(
    group_params,
    x,
    cfg,
    plans,
    *,
    positions,
    group_cache=None,
    cache_pos=None,
    kv_len=None,
    vision_embeds=None,
    collect_kv=False,
    attn_impl="dense",
    q_chunk=512,
    kv_chunk=1024,
    causal_skip=None,
):
    """Apply one period group.  Returns (x, new_group_cache, aux_losses)."""
    new_cache: dict[str, PyTree] = {}
    aux = {"moe_lb_loss": 0.0, "moe_z_loss": 0.0, "moe_drop_fraction": 0.0}
    num_moe = 0
    for i, plan in enumerate(plans):
        blk = group_params[f"block{i}"]
        h = _norm(cfg, blk.get("norm_mixer"), x)
        if plan.mixer == "attn":
            cache_i = None if group_cache is None else group_cache.get(f"block{i}")
            out, c_new, kv = _self_attn(
                blk["attn"], h, cfg,
                positions=positions, cache=cache_i, cache_pos=cache_pos,
                kv_len=kv_len, attn_impl=attn_impl,
                q_chunk=q_chunk, kv_chunk=kv_chunk, causal_skip=causal_skip,
            )
            if c_new is not None:
                new_cache[f"block{i}"] = c_new
            elif collect_kv:
                new_cache[f"block{i}"] = {"k": kv[0], "v": kv[1]}
        elif plan.mixer == "cross_attn":
            # Fresh vision embeds (prefill) take priority over cached K/V.
            vkv = None
            if vision_embeds is None and group_cache is not None:
                vkv = group_cache.get(f"block{i}")
            out, vkv_new = _cross_attn(
                blk["attn"], blk["xattn_gate"], h, cfg,
                vision_kv=vkv, vision_embeds=vision_embeds,
                attn_impl=attn_impl, q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            if collect_kv or group_cache is not None:
                new_cache[f"block{i}"] = vkv_new
        else:  # mamba
            cache_i = None if group_cache is None else group_cache.get(f"block{i}")
            out, c_new = S.ssm_block(blk["mamba"], h, cfg, cfg.ssm, cache=cache_i)
            if group_cache is not None or collect_kv:
                new_cache[f"block{i}"] = c_new
        x = L.constrain_res(x + out)

        if plan.ffn != "none":
            h = _norm(cfg, blk.get("norm_ffn"), x)
            if plan.ffn == "moe":
                out, moe_aux = M.moe_apply(blk["moe"], h, cfg.moe)
                for key in ("moe_lb_loss", "moe_z_loss", "moe_drop_fraction"):
                    aux[key] = aux[key] + moe_aux[key]
                num_moe += 1
            else:
                out = L.mlp(blk["mlp"], h)
            x = L.constrain_res(x + out)
    if num_moe:
        aux = {k: v / num_moe for k, v in aux.items()}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------


def _embed_input(params, cfg, tokens=None, embeds=None):
    if cfg.embeds_input:
        assert embeds is not None, "stubbed-frontend arch takes embeddings"
        return embeds.astype(cfg.dtype)
    return L.embed_lookup(params["embed"]["embedding"], tokens)


def forward(
    params,
    cfg: ArchConfig,
    *,
    tokens=None,
    embeds=None,
    vision_embeds=None,
    collect_kv=False,
    cache_pad_to=None,
    attn_impl="dense",
    q_chunk=512,
    kv_chunk=1024,
    causal_skip=None,
    remat=True,
    unroll=1,
):
    """Full-sequence forward.  Returns (logits, caches|None, aux)."""
    plans = block_plans(cfg)
    x = _embed_input(params, cfg, tokens, embeds)
    bsz, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    def group_fn(x, group_params):
        x, kv, aux = _apply_group(
            group_params, x, cfg, plans,
            positions=positions, vision_embeds=vision_embeds,
            collect_kv=collect_kv, attn_impl=attn_impl,
            q_chunk=q_chunk, kv_chunk=kv_chunk, causal_skip=causal_skip,
        )
        return x, (kv, aux)

    if remat:
        group_fn = jax.checkpoint(group_fn)
    x, (kvs, auxs) = lax.scan(group_fn, x, params["blocks"], unroll=unroll)
    aux = jax.tree.map(lambda a: jnp.mean(a), auxs)
    x = _norm(cfg, params.get("final_norm"), x)
    lg = L.logits(params.get("head"), params["embed"], x, cfg)

    caches = None
    if collect_kv:
        caches = kvs
        if cache_pad_to is not None:
            caches = jax.tree.map(
                partial(_pad_cache_seq, plans=plans, pad_to=cache_pad_to),
                caches,
            )
    return lg, caches, aux


def _pad_cache_seq(x, *, plans, pad_to):
    # pads K/V (groups,B,S,KV,dh) to (groups,B,pad_to,KV,dh); leaves others
    if x.ndim == 5 and x.shape[2] < pad_to:
        pad = [(0, 0)] * 5
        pad[2] = (0, pad_to - x.shape[2])
        return jnp.pad(x, pad)
    return x


def decode_step(
    params,
    caches,
    cfg: ArchConfig,
    *,
    tokens=None,
    embeds=None,
    lengths=None,
    attn_impl="dense",
    kv_chunk=1024,
    unroll=1,
):
    """One-token step.  tokens: (B,) int32 (or embeds (B,1,d)); lengths:
    (B,) current context length per sequence (cache write position).
    Returns (logits (B,V), new_caches)."""
    plans = block_plans(cfg)
    if cfg.embeds_input:
        x = embeds.astype(cfg.dtype)
        bsz = x.shape[0]
    else:
        x = L.embed_lookup(params["embed"]["embedding"], tokens)[:, None, :]
        bsz = tokens.shape[0]
    if lengths is None:
        lengths = jnp.zeros((bsz,), jnp.int32)
    positions = lengths[:, None]
    kv_len = (lengths + 1)[:, None]  # (B,1) valid kv after the write

    def group_fn(x, scan_in):
        group_params, group_cache = scan_in
        x, new_cache, aux = _apply_group(
            group_params, x, cfg, plans,
            positions=positions, group_cache=group_cache,
            cache_pos=lengths, kv_len=kv_len,
            attn_impl=attn_impl, kv_chunk=kv_chunk, q_chunk=1,
        )
        return x, new_cache

    x, new_caches = lax.scan(group_fn, x, (params["blocks"], caches), unroll=unroll)
    x = _norm(cfg, params.get("final_norm"), x)
    lg = L.logits(params.get("head"), params["embed"], x, cfg)
    return lg[:, 0, :], new_caches


def _cache_seq_len(caches):
    for blk in caches.values():
        if "k" in blk:
            return blk["k"].shape[2]
    return None


def prefill_step(
    params,
    caches,
    cfg: ArchConfig,
    *,
    tokens=None,
    embeds=None,
    pos=0,
    vision_embeds=None,
    attn_impl="chunked",
    q_chunk=512,
    kv_chunk=1024,
    unroll=1,
):
    """Chunked streaming prefill: process a prompt chunk at offset ``pos``.

    The chunk sequence is a bounded stream whose carried value is the
    KV/SSD cache (the paper's construct on the sequence axis): chunk c's
    attention forces the cache future produced by chunk c-1.
    tokens: (B, C).  Returns (last-position logits (B,V), new caches).
    """
    plans = block_plans(cfg)
    if cfg.embeds_input:
        x = embeds.astype(cfg.dtype)
    else:
        x = L.embed_lookup(params["embed"]["embedding"], tokens)
    bsz, s, _ = x.shape
    static_pos = isinstance(pos, int)
    if not static_pos:
        pos = jnp.asarray(pos, jnp.int32)
    positions = (pos + jnp.arange(s))[None, :]
    # Whole-cache prefill (pos 0, chunk covers the buffer): no padding to
    # mask and a static zero offset — unlocks causal block skipping.
    full_cover = static_pos and pos == 0 and _cache_seq_len(caches) == s
    kv_len = None if full_cover else pos + s

    def group_fn(x, scan_in):
        group_params, group_cache = scan_in
        x, new_cache, _ = _apply_group(
            group_params, x, cfg, plans,
            positions=positions, group_cache=group_cache,
            cache_pos=pos, kv_len=kv_len, vision_embeds=vision_embeds,
            attn_impl=attn_impl, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return x, new_cache

    x, new_caches = lax.scan(group_fn, x, (params["blocks"], caches), unroll=unroll)
    x = _norm(cfg, params.get("final_norm"), x)
    lg = L.logits(params.get("head"), params["embed"], x[:, -1:, :], cfg)
    return lg[:, 0, :], new_caches
