"""Logical-axis sharding rules (GSPMD/pjit layer).

Weights are 2-D sharded (FSDP over ``data`` × TP over ``model``) — ZeRO-3
style: optimizer state and gradients inherit the same sharding, which is
what lets the 398 B/400 B configs fit 16 GB/chip on the 256-chip pod.

Rule sets are plain dicts ``logical axis -> mesh axis (or tuple or None)``;
per-shape overrides (e.g. decode shards the KV-cache sequence dim over
``model``; long-context batch=1 shards it over ``data`` too) are expressed
as dict updates, not code.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.models import params as P_

PyTree = Any

# Base rules: training / prefill on the production mesh.
TRAIN_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": "data",       # FSDP shard of the d_model dim of weights
    "mlp_in": "data",      # FSDP shard of non-model dims
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    # Untied input-embedding table: FSDP the rows over `data`; the input
    # gather then costs one transient table replication (SPMD last-resort
    # replicate-then-gather — compiles everywhere; an embed-dim-sharded
    # table instead trips the CPU partitioner on the gather+reshard).
    # Baseline inefficiency, attacked in §Perf.
    "vocab_table": "data",
    "embed_table": None,
    "experts": "model",    # expert parallelism folded onto the TP axis
    "layers": None,
    "stage": "pod",        # pipeline stages (stream-future mode)
    "seq": None,
    "act_seq": "model",    # sequence-parallel activations between blocks
    "kv_seq": None,
    "conv": None,
    "state": None,
    "groups": None,
}

# Decode: KV cache sequence dim sharded over the TP axis (flash-decoding
# style split-K combine is left to GSPMD's partial softmax reductions).
# kv_heads must then stay unsharded — one mesh axis per spec position.
DECODE_RULES = dict(TRAIN_RULES, kv_seq="model", kv_heads=None, act_seq=None)

# Prefill: cache written across the whole sequence; shard it like decode.
PREFILL_RULES = dict(TRAIN_RULES, kv_seq="model", kv_heads=None)

# Long-context decode with global_batch=1: batch axes would idle, so the
# KV/state sequence shards over every axis (512k / 512 = 1k per chip).
LONG_DECODE_RULES = dict(
    DECODE_RULES, batch=None, kv_seq=("pod", "data", "model")
)


def spec_for(logical_axes: tuple[str | None, ...], rules: Mapping[str, Any]) -> P:
    parts = []
    for ax in logical_axes:
        if ax is None:
            parts.append(None)
        else:
            if ax not in rules:
                raise KeyError(f"no sharding rule for logical axis {ax!r}")
            parts.append(rules[ax])
    # Drop trailing Nones for tidiness.
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def prune_spec(spec: P, mesh: Mesh) -> P:
    """Remove mesh axes that don't exist in ``mesh`` (single-pod has no 'pod')."""
    parts = []
    for part in spec:
        if part is None:
            parts.append(None)
        elif isinstance(part, tuple):
            kept = tuple(a for a in part if a in mesh.axis_names)
            # normalize singleton tuples: modern PartitionSpec does this
            # internally, 0.4.x does not — keep both spellings equal
            parts.append(
                None if not kept else (kept[0] if len(kept) == 1 else kept)
            )
        else:
            parts.append(part if part in mesh.axis_names else None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Make a spec legal for ``shape`` on ``mesh``.

    * drops mesh axes whose product does not evenly divide the dim
      (e.g. 20 q-heads or a 50280-row tied vocab on model=16 — the dim
      stays replicated; a recorded inefficiency, see DESIGN §5), and
    * de-duplicates mesh axes across positions (first occurrence wins).
    """
    spec = prune_spec(spec, mesh)
    used: set[str] = set()
    parts = []
    for d, part in enumerate(list(spec) + [None] * (len(shape) - len(spec))):
        axes = () if part is None else (part if isinstance(part, tuple) else (part,))
        axes = tuple(a for a in axes if a not in used)
        # drop axes from the right until the product divides the dim
        while axes and shape[d] % int(
            np.prod([mesh.shape[a] for a in axes])
        ) != 0:
            axes = axes[:-1]
        used.update(axes)
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(axes)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_pspecs(layout: PyTree, rules: Mapping[str, Any], mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: fit_spec(spec_for(s.logical_axes, rules), s.shape, mesh),
        layout,
        is_leaf=P_.is_spec,
    )


def param_shardings(layout: PyTree, rules: Mapping[str, Any], mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, fit_spec(spec_for(s.logical_axes, rules), s.shape, mesh)
        ),
        layout,
        is_leaf=P_.is_spec,
    )


def maybe_constrain(x, spec: P):
    """with_sharding_constraint, a no-op when no mesh is in context.

    Lets model code carry sharding annotations that activate under the
    production mesh but stay inert in single-device smoke tests.  Inside a
    partial-manual shard_map region (the stream-future pipeline), manual
    axes are already local and must be dropped from the spec.
    """
    import os
    if os.environ.get("REPRO_NO_CONSTRAIN") == "1":
        return x
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    manual = compat.manual_axis_names(mesh)
    if manual:
        parts = []
        for part in spec:
            axes = () if part is None else (
                part if isinstance(part, tuple) else (part,)
            )
            axes = tuple(a for a in axes if a not in manual)
            parts.append(
                None if not axes else (axes[0] if len(axes) == 1 else axes)
            )
        spec = P(*parts)
    return jax.lax.with_sharding_constraint(x, prune_spec(spec, mesh))


def shard_activation(x, logical_axes, rules, mesh=None):
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    spec = spec_for(logical_axes, rules)
    if mesh is not None:
        spec = prune_spec(spec, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return maybe_constrain(x, spec)
