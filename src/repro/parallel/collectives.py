"""Collective helpers: overlap idioms and ring primitives.

These wrap the Future combinators of :mod:`repro.core.future` into the
shapes distributed layers want.  Under ``shard_map`` the futures are real
async collectives on TPU (``collective-permute-start/done``); under plain
pjit, GSPMD owns the schedule and these reduce to ordinary ops.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.future import defer

PyTree = Any


def ring_all_gather_overlapped(
    x: jnp.ndarray,
    axis_name: str,
    compute_fn: Callable[[jnp.ndarray, int], jnp.ndarray],
):
    """All-gather by ring permute, overlapping ``compute_fn`` per shard.

    ``compute_fn(shard, slot)`` consumes each peer's shard as it arrives —
    the paper's stream: each arriving shard is a cell, the in-flight
    permute is the future tail.  Returns the list of per-slot results.
    Used for FSDP-style layer compute where the weight shard arriving
    next overlaps the matmul on the current one.
    """
    size = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % size) for i in range(size)]
    results = []
    shard = x
    for hop in range(size):
        # start moving the next shard now (future) ...
        fut = defer(lambda s: lax.ppermute(s, axis_name, perm), shard)
        # ... while computing on the current one
        slot = (idx - hop) % size
        results.append(compute_fn(shard, slot))
        shard = fut.force(anchor=results[-1])
    return results


def reduce_scatter_then_all_gather(x: jnp.ndarray, axis_name: str):
    """The SP decomposition of an all-reduce: psum_scatter + all_gather.

    Splitting lets the two halves straddle the residual compute between
    them (Megatron sequence parallelism); callers place compute between
    the returned future's creation and force.
    """
    scattered = lax.psum_scatter(x, axis_name, tiled=True)
    return defer(lambda s: lax.all_gather(s, axis_name, tiled=True), scattered)


def pod_allreduce_compressed(grads: PyTree, axis_name: str, error: PyTree | None):
    """Cross-pod gradient all-reduce in bf16 with error feedback."""
    from repro.train.compression import compress_decompress

    q, new_error = compress_decompress(grads, error)
    reduced = jax.tree.map(
        lambda g: lax.pmean(g.astype(jnp.bfloat16), axis_name).astype(jnp.float32),
        q,
    )
    return reduced, new_error
