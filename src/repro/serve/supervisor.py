"""Round-supervised serving: zero-loss fault recovery for the engines.

``ServeSupervisor`` wraps any serving engine (``Engine`` or
``StreamEngine`` — anything with the ``submit/step/run_until_drained``
contract and host-visible state) with the serving half of the
:mod:`repro.resilience` runbook:

* **snapshot/restore** — before every round, the complete in-flight
  state is snapshotted to host memory: the KV caches / cell states, the
  slot bookkeeping (``lengths``/``active``), the admission queue, the
  uid counter, and every live request's mutable fields.  A failed round
  restores the snapshot and replays.  Replay is *bitwise*: sampling
  derives from ``(seed, uid, ngen)`` (see ``sample_token``), admissions
  re-plan identically from the restored queue, and prefill/decode are
  deterministic — so a recovered serve emits exactly the tokens of a
  fault-free run.
* **watchdog deadline** — a round slower than ``deadline_s`` is treated
  as wedged: its results are discarded (snapshot restore) and the round
  replays.  Detection here is at the round boundary (single-process
  container); the in-flight heartbeat file
  (:class:`repro.resilience.Heartbeat`) is the channel an *external*
  supervisor uses to SIGKILL a worker that never reaches the boundary.
* **numerics poisoning** — after each round the engine's float cache
  state is checked for NaN/inf; a poisoned round restores and replays
  (with the poison source gone, e.g. a transient hardware fault, the
  replay is clean and bitwise).
* **bounded retry with backoff** — each round gets a fresh
  :class:`repro.resilience.RestartBudget`; an exhausted budget re-raises
  and counts the unresolved accepted requests in
  ``stats["requests_lost"]`` (the chaos gate pins this to zero).
* **graceful SIGTERM drain** — ``install_signal_handlers()`` turns
  SIGTERM into "stop accepting, finish everything accepted": ``submit``
  starts raising :class:`DrainingError`, and the drain loop runs every
  queued + in-flight request to completion before returning.

Fault injection (the chaos battery's entry point) is a
:mod:`repro.resilience.injection` callable invoked with
``(round_index, engine)`` before each round attempt —
:func:`chaos_injector` builds the standard fault classes.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.resilience import Heartbeat, RestartBudget, RestartPolicy, StragglerTracker
from repro.resilience.injection import InjectedFault, OneShotInjector, call_injector
from repro.serve.engine import DrainTimeoutError, Request

PyTree = Any


class RoundFault(RuntimeError):
    """Base class for supervisor-detected round failures."""


class WatchdogTimeout(RoundFault):
    """The round exceeded the supervisor's deadline (wedge)."""


class NumericsFault(RoundFault):
    """NaN/inf detected in the engine's cache state after a round."""


class DrainingError(RuntimeError):
    """submit() after SIGTERM/drain was requested (admission closed)."""


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    deadline_s: float | None = None   # round watchdog; None disables
    max_restarts: int = 3             # per-round retry budget
    backoff_seconds: float = 0.0      # retry backoff (0 = immediate)
    backoff_factor: float = 2.0
    check_numerics: bool = True       # NaN/inf cache scan per round
    heartbeat_path: str | None = None
    straggler_factor: float = 2.0     # round-time EMA surfacing


@dataclasses.dataclass
class Snapshot:
    """Host-side copy of the complete in-flight engine state."""

    device: PyTree                      # cache (Engine) / cell_states (Stream)
    lengths: np.ndarray
    active_uids: list[int | None]
    queue_uids: list[int]
    requests: dict[int, Request]        # uid -> live handle
    req_state: dict[int, tuple[list[int], bool, str]]  # mutable fields
    uid_counter: int


def _device_state(engine) -> PyTree:
    """The engine's device-resident mutable state (cache shards)."""
    return engine.cell_states if hasattr(engine, "cell_states") else engine.cache


def _set_device_state(engine, tree: PyTree) -> None:
    if hasattr(engine, "cell_states"):
        engine.cell_states = tree
    else:
        engine.cache = tree


class ServeSupervisor:
    """Wrap an engine with snapshot/replay fault recovery.

    The supervisor owns the step loop: call ``submit``/``cancel``/
    ``step``/``run_until_drained`` on the supervisor, not the engine.
    Each ``step()`` is one supervised round: snapshot, (optionally
    inject,) run, verify deadline + numerics — and on any fault,
    restore + replay under a bounded restart budget.
    """

    def __init__(
        self,
        engine,
        cfg: SupervisorConfig | None = None,
        fail_injector: Callable | None = None,
        on_event: Callable[[dict], None] | None = None,
    ):
        self.engine = engine
        self.cfg = cfg or SupervisorConfig()
        self.fail_injector = fail_injector
        self.on_event = on_event
        self.events: list[dict] = []
        self.stats = {
            "rounds": 0, "faults": 0, "restarts": 0,
            "requests_lost": 0, "stragglers": 0,
        }
        self._round_idx = 0
        self._draining = False
        self._hb = Heartbeat(self.cfg.heartbeat_path)
        self._straggler = StragglerTracker(self.cfg.straggler_factor)
        self._policy = RestartPolicy(
            max_restarts=self.cfg.max_restarts,
            backoff_seconds=self.cfg.backoff_seconds,
            backoff_factor=self.cfg.backoff_factor,
        )

    # -- lifecycle -----------------------------------------------------------

    def install_signal_handlers(self):
        signal.signal(signal.SIGTERM, self.request_drain)

    def request_drain(self, *_):
        """SIGTERM handler: close admission, keep serving until drained."""
        if not self._draining:
            self._draining = True
            self._event({"event": "drain_requested"})

    @property
    def draining(self) -> bool:
        return self._draining

    def submit(self, *args, **kwargs) -> Request:
        if self._draining:
            raise DrainingError("supervisor is draining; admission closed")
        return self.engine.submit(*args, **kwargs)

    def cancel(self, uid: int) -> bool:
        return self.engine.cancel(uid)

    def drained(self) -> bool:
        eng = self.engine
        return not eng.queue and all(r is None for r in eng.active)

    # -- snapshot / restore --------------------------------------------------

    def snapshot(self) -> Snapshot:
        """Copy the complete in-flight state to host memory.

        ``np.array`` (not ``asarray``) so the copy never aliases device
        buffers — restore after a donated round must not read reused
        memory.
        """
        eng = self.engine
        live: dict[int, Request] = {}
        for req in list(eng.queue) + [r for r in eng.active if r is not None]:
            live[req.uid] = req
        return Snapshot(
            device=jax.tree.map(
                lambda l: np.array(l), jax.device_get(_device_state(eng))
            ),
            lengths=eng.lengths.copy(),
            active_uids=[r.uid if r is not None else None for r in eng.active],
            queue_uids=[r.uid for r in eng.queue],
            requests=live,
            req_state={
                uid: (list(r.out_tokens), r.done, r.status)
                for uid, r in live.items()
            },
            uid_counter=eng._uid,
        )

    def restore(self, snap: Snapshot) -> None:
        """Roll the engine (and every live request handle) back."""
        eng = self.engine
        _set_device_state(eng, jax.tree.map(jnp.asarray, snap.device))
        eng.lengths = snap.lengths.copy()
        for uid, (toks, done, status) in snap.req_state.items():
            req = snap.requests[uid]
            req.out_tokens = list(toks)
            req.done = done
            req.status = status
        eng.active = [
            snap.requests[uid] if uid is not None else None
            for uid in snap.active_uids
        ]
        eng.queue.clear()
        eng.queue.extend(snap.requests[uid] for uid in snap.queue_uids)
        eng._uid = snap.uid_counter
        if hasattr(eng, "_by_uid"):
            eng._by_uid = {
                r.uid: r for r in eng.active if r is not None
            }

    # -- fault detection -----------------------------------------------------

    def _check_numerics(self):
        """NaN/inf scan over the engine's float cache state.  One
        all-reduce per leaf; skipped when ``check_numerics`` is off."""
        for leaf in jax.tree.leaves(_device_state(self.engine)):
            if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
                continue
            if not bool(jnp.isfinite(leaf).all()):
                raise NumericsFault(
                    "non-finite values in engine cache state "
                    "(poisoned logits/KV rows)"
                )

    def _event(self, ev: dict):
        self.events.append(ev)
        if self.on_event:
            self.on_event(ev)

    def _unresolved(self) -> list[int]:
        eng = self.engine
        return sorted(
            [r.uid for r in eng.queue if not r.done]
            + [r.uid for r in eng.active if r is not None and not r.done]
        )

    # -- the supervised round ------------------------------------------------

    def step(self) -> list[Request]:
        """One supervised round: snapshot → run → verify, replay on fault."""
        snap = self.snapshot()
        budget = RestartBudget(self._policy)
        while True:
            t0 = time.monotonic()
            try:
                call_injector(self.fail_injector, self._round_idx, self.engine)
                finished = self.engine.step()
                dt = time.monotonic() - t0
                if self.cfg.deadline_s is not None and dt > self.cfg.deadline_s:
                    raise WatchdogTimeout(
                        f"round {self._round_idx} took {dt:.3f}s "
                        f"> deadline {self.cfg.deadline_s}s"
                    )
                if self.cfg.check_numerics:
                    self._check_numerics()
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # noqa: BLE001 — any fault: replay
                self.stats["faults"] += 1
                self._event({
                    "event": "round_fault", "round": self._round_idx,
                    "error": f"{type(e).__name__}: {e}",
                    "attempt": budget.restarts,
                })
                if not budget.admit():
                    lost = self._unresolved()
                    self.stats["requests_lost"] += len(lost)
                    self._event({
                        "event": "gave_up", "round": self._round_idx,
                        "requests_lost": lost,
                    })
                    raise
                self.stats["restarts"] += 1
                time.sleep(budget.next_delay())
                self.restore(snap)
                continue
            if self._straggler.observe(self._round_idx, dt):
                self.stats["stragglers"] += 1
            self._hb.beat(self._round_idx)
            self._round_idx += 1
            self.stats["rounds"] += 1
            return finished

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Drain every accepted request under supervision.

        When draining was requested (SIGTERM), this is the graceful
        exit: everything accepted completes, nothing new enters.
        """
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if self.drained():
                if self._draining:
                    self._event({"event": "drained"})
                return finished
        undrained = self._unresolved()
        self.stats["requests_lost"] += len(undrained)
        raise DrainTimeoutError(max_steps, undrained)


# -- chaos injection (the standard fault classes) ----------------------------


def poison_cache(engine) -> None:
    """NaN-poison the engine's float cache state (simulated bad HBM /
    overflowed logits).  Detection is the supervisor's numerics scan."""
    poisoned = jax.tree.map(
        lambda l: (
            jnp.full_like(l, jnp.nan)
            if jnp.issubdtype(jnp.asarray(l).dtype, jnp.inexact)
            else l
        ),
        _device_state(engine),
    )
    _set_device_state(engine, poisoned)


def chaos_injector(
    kind: str, at_round: int, *, wedge_seconds: float = 1.0
) -> OneShotInjector:
    """The chaos battery's fault classes, as one-shot injectors.

    * ``"raise"``   — the round attempt raises :class:`InjectedFault`
      (a mid-round exception: kernel crash, collective failure, ...).
    * ``"nan"``     — the cache state is NaN-poisoned before the round;
      the numerics scan catches it after.
    * ``"wedge"``   — the round stalls ``wedge_seconds`` (must exceed
      the supervisor's ``deadline_s`` to trip the watchdog).
    * ``"sigterm"`` — SIGTERM is delivered to this process mid-serve;
      with handlers installed the supervisor drains gracefully.
    """
    def _raise(eng):
        raise InjectedFault(f"injected round failure at round {at_round}")

    actions = {
        "raise": _raise,
        "nan": poison_cache,
        "wedge": lambda eng: time.sleep(wedge_seconds),
        "sigterm": lambda eng: os.kill(os.getpid(), signal.SIGTERM),
    }
    if kind not in actions:
        raise ValueError(f"chaos kind {kind!r}; expected one of {sorted(actions)}")
    return OneShotInjector(at_round, actions[kind])
