"""Serving engines: continuous batching over a slotted KV cache.

The engine is the paper's construct at the request level: each submitted
request returns a *future* (its completion), the decode loop is the
stream, and chunked prefill (``prefill_chunk``) is the §7 chunk-size knob
balancing time-to-first-token against decode-step latency.

Two engines share one continuous-batching contract (``submit`` /
``step`` / ``run_until_drained``) and produce bit-identical greedy
outputs:

``Engine`` — the layer-sequential reference.  One monolithic jitted
``decode_step`` per decode step over all ``max_batch`` slots; admission,
sampling and retirement run in host Python between steps.

``StreamEngine`` — decode as a Stream program.  The transformer's layer
groups split into ``num_cells`` pipeline cells (params ride the chain's
read-only ``const_state``; each cell's cache shard is its mutable
Stream state, updated by row-level scatters only), the batch splits into
``microbatches`` in-flight items, and one ``Stream.feedback`` program
executes ``round_steps`` decode steps per device-program invocation:
the emitted token re-enters as the next item (lag = microbatches), and a
zipped *admission overlay* source plus per-cell admission buffers admit
freshly prefilled requests into retired slots **inside the plan** —
continuous batching realized by the schedule's feed carousel, not by
host Python.  Under ``FutureEvaluator`` the cells pipeline across a mesh
axis (gpipe / interleaved), hiding per-layer-group latency exactly as
the paper's Future substitution promises; under ``LazyEvaluator`` the
same program runs layer-sequentially on one device (the baseline
``bench_serve`` measures against).

Common architecture:
  * ``max_batch`` cache slots; per-slot length/active/eos state on host.
  * admit: new requests prefill in chunks (B=1, ragged tail padded to a
    single masked chunk) and enter a free slot — by host scatter
    (``Engine``) or by in-plan install (``StreamEngine``).
  * retire: slots retire on EOS, exhausted budget, or the ``max_len``
    cache boundary — including on the prefill-sampled first token; their
    futures resolve.
  * sampling: greedy argmax, or temperature sampling whose RNG derives
    from ``(seed, request uid, token index)`` — reproducible per request
    regardless of admission order, batching, or evaluator.

Request-lifecycle robustness (see also :mod:`repro.serve.supervisor`
for round-level fault recovery):
  * **bounded admission** — ``ServeConfig.max_queue`` caps the host
    queue; ``submit`` raises :class:`QueueFullError` (explicit load
    shedding) instead of queueing unboundedly under overload.
  * **deadlines** — ``submit(..., deadline_s=...)`` attaches a
    wall-clock budget; expired requests resolve with
    ``status="expired"`` at the next step boundary instead of holding a
    slot forever.
  * **cancellation** — ``cancel(uid)`` retires a queued or in-flight
    request through the normal retirement machinery (its slot frees for
    the next admission; takes effect at the next step/round boundary).
  * **degraded mode** — a ``kernels="pallas"`` StreamEngine whose fused
    kernels fail to dispatch falls back to the bitwise-identical
    ``"xla"`` path, recording a degradation event, instead of taking
    the engine down.
  * **honest drain** — ``run_until_drained`` raises
    :class:`DrainTimeoutError` naming the undrained uids when
    ``max_steps`` expires with requests still in flight, instead of
    silently truncating.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, DecodePipelineConfig
from repro.core import FutureEvaluator, LazyEvaluator, Stream
from repro.kernels import resolve_mode
from repro.models import layers as L
from repro.models import transformer as T

PyTree = Any


class QueueFullError(RuntimeError):
    """Load shedding: the admission queue is at ``max_queue``."""


class DrainTimeoutError(RuntimeError):
    """``run_until_drained`` hit ``max_steps`` with requests in flight."""

    def __init__(self, max_steps: int, undrained: list[int]):
        self.max_steps = max_steps
        self.undrained = undrained
        super().__init__(
            f"not drained after {max_steps} steps; "
            f"undrained request uids: {undrained}"
        )


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 1024
    prefill_chunk: int = 128
    max_new_tokens: int = 64
    eos_id: int = -1  # -1: never; run to max_new_tokens
    temperature: float = 0.0  # 0 => greedy
    attn_impl: str = "dense"
    seed: int = 0
    max_queue: int | None = None  # None: unbounded admission queue


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    deadline: float | None = None  # absolute time.monotonic() budget
    status: str = "ok"  # "ok" | "cancelled" | "expired"


def sample_token(logits, temperature: float, seed: int, uid, ngen):
    """Sample the next token; reproducible per request.

    Greedy (``temperature <= 0``) is a plain argmax.  Temperature
    sampling derives its RNG key from ``(seed, uid, ngen)`` — the
    request uid and its token index — so retries, batch-mates, admission
    order and pipelined execution all sample identically.  ``logits``
    may be one row ``(V,)`` or a batch ``(B, V)`` with per-row
    uid/ngen; both engines call this one function (the StreamEngine from
    inside its emit), so host and device sampling share one code path.
    """
    logits = jnp.asarray(logits)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    uid = jnp.asarray(uid, jnp.int32)
    ngen = jnp.asarray(ngen, jnp.int32)

    def one(lg, u, g):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), u), g
        )
        return jax.random.categorical(key, lg / temperature).astype(jnp.int32)

    if logits.ndim == 1:
        return one(logits, uid, ngen)
    return jax.vmap(one)(logits, uid, ngen)


class _EngineBase:
    """Shared request bookkeeping + chunked prefill."""

    def __init__(self, params, cfg: ArchConfig, scfg: ServeConfig):
        assert not cfg.embeds_input, "engine serves token-input archs"
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.lengths = np.zeros(scfg.max_batch, np.int32)
        self.active: list[Request | None] = [None] * scfg.max_batch
        self.queue: deque[Request] = deque()
        self._uid = 0
        # Lifecycle event log: degradations, load sheds, cancellations,
        # expiries — host-side observability, never on the device path.
        self.events: list[dict] = []
        # logits_at is passed traced (not static) so every ragged-tail
        # length shares one compiled prefill per chunk width.
        self._prefill = jax.jit(
            partial(T.prefill_step, cfg=cfg, attn_impl=scfg.attn_impl)
        )

    # -- public API ----------------------------------------------------------

    def submit(
        self,
        prompt: np.ndarray,
        max_new_tokens: int | None = None,
        deadline_s: float | None = None,
    ) -> Request:
        """Returns the request handle (its .done flag is the future).

        ``deadline_s`` is a wall-clock budget from submission; an
        expired request resolves with ``status="expired"`` at the next
        step boundary.  With ``max_queue`` set, an over-full queue
        raises :class:`QueueFullError` — acceptance is explicit, so
        "zero accepted requests lost" is a meaningful contract.
        """
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if len(prompt) >= self.scfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} needs >= 1 free cache row; "
                f"max_len={self.scfg.max_len}"
            )
        mq = self.scfg.max_queue
        if mq is not None and len(self.queue) >= mq:
            self.events.append({"event": "load_shed", "queue": len(self.queue)})
            raise QueueFullError(
                f"admission queue full ({len(self.queue)} >= max_queue={mq})"
            )
        req = Request(
            uid=self._uid,
            prompt=prompt,
            max_new_tokens=max_new_tokens or self.scfg.max_new_tokens,
            deadline=(
                None if deadline_s is None else time.monotonic() + deadline_s
            ),
        )
        self._uid += 1
        self.queue.append(req)
        return req

    def cancel(self, uid: int) -> bool:
        """Retire a queued or in-flight request host-side.

        The request resolves immediately (``done=True``,
        ``status="cancelled"``, tokens so far kept); an occupied slot is
        released through the normal retirement machinery, so the next
        admission reuses it.  For the StreamEngine the device round in
        progress is untouched — the cancelled slot simply stops
        re-entering at the next round boundary, exactly like an EOS
        retirement.  Returns False for unknown/finished uids.
        """
        for req in list(self.queue):
            if req.uid == uid and not req.done:
                self.queue.remove(req)
                req.done, req.status = True, "cancelled"
                self.events.append({"event": "cancel", "uid": uid})
                return True
        for slot, req in enumerate(self.active):
            if req is not None and req.uid == uid and not req.done:
                req.done, req.status = True, "cancelled"
                self._retire_slot(slot)
                self.events.append({"event": "cancel", "uid": uid})
                return True
        return False

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self.queue and all(r is None for r in self.active):
                return finished
        undrained = sorted(
            [r.uid for r in self.queue]
            + [r.uid for r in self.active if r is not None]
        )
        raise DrainTimeoutError(max_steps, undrained)

    def step(self) -> list[Request]:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- internals -----------------------------------------------------------

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _retire_slot(self, slot: int) -> None:
        """Release a slot host-side (cancel/expiry); cache rows are
        stale-but-inert until the next admission overwrites them."""
        self.active[slot] = None

    def _expire_deadlines(self) -> list[Request]:
        """Resolve requests whose deadline has passed; returns them.
        Called at each step boundary — queued requests are dropped
        before ever prefetching, in-flight ones retire their slot."""
        now = time.monotonic()
        expired = []
        for req in list(self.queue):
            if req.deadline is not None and now >= req.deadline:
                self.queue.remove(req)
                req.done, req.status = True, "expired"
                expired.append(req)
        for slot, req in enumerate(self.active):
            if req is not None and req.deadline is not None and now >= req.deadline:
                req.done, req.status = True, "expired"
                self._retire_slot(slot)
                expired.append(req)
        if expired:
            self.events.append(
                {"event": "expired", "uids": [r.uid for r in expired]}
            )
        return expired

    def _sample_host(self, logits_row: np.ndarray, uid: int, ngen: int) -> int:
        if self.scfg.temperature <= 0:
            # Same first-max tie-breaking as jnp.argmax in the device
            # emit, without a per-slot device dispatch on the hot path.
            return int(np.argmax(logits_row))
        return int(
            sample_token(
                logits_row, self.scfg.temperature, self.scfg.seed, uid, ngen
            )
        )

    def _prefill_single(self, req: Request) -> tuple[PyTree, bool]:
        """Chunked prefill of one request into a fresh single-slot cache.

        Full ``prefill_chunk``-sized chunks stream through the cache; the
        ragged tail (``plen % prefill_chunk``) is padded to one masked
        chunk whose logits are read at the last real position — one call
        instead of one B=1 decode per tail token, which is where most of
        a short prompt's TTFT went (see ``benchmarks/bench_serve.py``).
        Samples the first token (ngen=0) and applies retirement to it:
        EOS, a budget of 1, or a prompt at the ``max_len`` boundary
        complete without ever occupying a batch slot.
        Returns ``(single_cache, done)``.
        """
        ck = self.scfg.prefill_chunk
        prompt = req.prompt
        plen = len(prompt)
        full = (plen // ck) * ck
        single = T.init_cache(self.cfg, 1, self.scfg.max_len)
        logits = None
        for c in range(full // ck):
            chunk = jnp.asarray(prompt[None, c * ck : (c + 1) * ck])
            logits, single = self._prefill(
                self.params, single, tokens=chunk, pos=c * ck
            )
        rem = plen - full
        if rem:
            # Pad the tail to one masked chunk — clamped to the cache
            # end so the write can never clamp-and-corrupt earlier rows
            # when max_len is not a multiple of the chunk size.
            width = min(ck, self.scfg.max_len - full)
            tail = np.zeros((1, width), np.int32)
            tail[0, :rem] = prompt[full:]
            logits, single = self._prefill(
                self.params, single,
                tokens=jnp.asarray(tail), pos=full,
                logits_at=jnp.asarray(rem - 1, jnp.int32),
            )
        tok = self._sample_host(np.asarray(logits)[0], req.uid, 0)
        req.out_tokens.append(tok)
        done = (
            len(req.out_tokens) >= req.max_new_tokens
            or tok == self.scfg.eos_id
            or plen + 1 >= self.scfg.max_len
        )
        return single, done


class Engine(_EngineBase):
    """Layer-sequential reference engine (monolithic jitted decode_step)."""

    def __init__(self, params, cfg: ArchConfig, scfg: ServeConfig):
        super().__init__(params, cfg, scfg)
        self.cache = T.init_cache(cfg, scfg.max_batch, scfg.max_len)
        self._decode = jax.jit(
            partial(T.decode_step, cfg=cfg, attn_impl=scfg.attn_impl)
        )

    # -- internals -----------------------------------------------------------

    def _admit(self) -> list[Request]:
        finished = []
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            req = self.queue.popleft()
            single, done = self._prefill_single(req)
            if done:
                req.done = True
                finished.append(req)
                continue  # slot stays free for the next queued request
            # Scatter this request's cache rows into the batch cache.
            def insert(batch_leaf, single_leaf):
                return batch_leaf.at[:, slot].set(single_leaf[:, 0])

            self.cache = jax.tree.map(insert, self.cache, single)
            self.lengths[slot] = len(req.prompt)
            self.active[slot] = req
        return finished

    def step(self) -> list[Request]:
        """Admit, one batched decode step, retire. Returns newly finished."""
        finished = self._expire_deadlines()
        finished.extend(self._admit())
        slots = [i for i, r in enumerate(self.active) if r is not None]
        if not slots:
            return finished
        # last token per active slot (prefill-sampled or last generated)
        tokens = np.zeros(self.scfg.max_batch, np.int32)
        for i in slots:
            tokens[i] = self.active[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, self.cache,
            tokens=jnp.asarray(tokens),
            lengths=jnp.asarray(self.lengths),
        )
        logits = np.asarray(logits)
        if self.scfg.temperature > 0:
            # One batched draw for all active slots (the same vmapped
            # path the StreamEngine's emit uses) instead of a per-slot
            # device dispatch on the decode hot path.
            uids = np.array([self.active[i].uid for i in slots], np.int32)
            ngens = np.array(
                [len(self.active[i].out_tokens) for i in slots], np.int32
            )
            drawn = np.asarray(
                sample_token(
                    logits[slots], self.scfg.temperature, self.scfg.seed,
                    uids, ngens,
                )
            )
            sampled = dict(zip(slots, drawn))
        else:
            sampled = {i: np.argmax(logits[i]) for i in slots}
        for i in slots:
            req = self.active[i]
            self.lengths[i] += 1
            tok = int(sampled[i])
            req.out_tokens.append(tok)
            hit_eos = tok == self.scfg.eos_id
            full = self.lengths[i] + 1 >= self.scfg.max_len
            if len(req.out_tokens) >= req.max_new_tokens or hit_eos or full:
                req.done = True
                finished.append(req)
                self.active[i] = None
        return finished


def decode_copy_bytes_per_tick(
    cfg: ArchConfig,
    microbatch: int,
    num_cells: int,
    *,
    row_scatter: bool = True,
    max_len: int = 1024,
) -> int:
    """Bytes one steady decode tick writes into its cell's cache shard.

    Under the row-scatter update scheme (the shipped hot path) a tick
    writes exactly one cache row per sequence per layer — the
    ``max_len=1`` cache layout *is* that row set, so its byte count over
    ``num_cells`` is the per-tick traffic.  Cross-attention vision K/V
    never changes during decode (``scatter_decode_rows`` skips it), so
    its leaves are excluded from the row set.  ``row_scatter=False``
    models the slab scheme this replaced (slice-out/slice-in of the
    whole microbatch block, vision K/V included — the old path rewrote
    it): the attention/SSM leaves at full ``max_len`` — a ``max_len``×
    larger term.  Feed the result through
    :func:`repro.core.chunking.copy_time_per_tick` into
    :func:`repro.core.chunking.optimal_schedule`'s ``per_tick_copy``.
    """
    layout = T.cache_layout(cfg, microbatch, 1 if row_scatter else max_len)
    if row_scatter:
        plans = T.block_plans(cfg)
        layout = {
            key: blk
            for key, blk in layout.items()
            if plans[int(key.removeprefix("block"))].mixer != "cross_attn"
        }
    total = sum(
        int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(layout)
    )
    return total // num_cells


def suggest_decode_pipeline(
    cfg: ArchConfig,
    *,
    devices: int,
    work_per_item: float,
    per_tick_overhead: float,
    microbatch: int,
    num_cells: int,
    copy_bytes_per_second: float = 50e9,
    max_len: int = 1024,
    row_scatter: bool = True,
    max_chunks: int = 64,
):
    """Pick a decode (schedule, M, V) with the cache-traffic term included.

    Thin serving-side threading of the chunking cost model: converts the
    per-tick copy bytes of the decode cells (row-scatter or slab) into a
    time term and hands it to
    :func:`repro.core.chunking.optimal_schedule`.  Returns a
    :class:`repro.core.chunking.ScheduleChoice`.
    """
    from repro.core import chunking

    per_tick_copy = chunking.copy_time_per_tick(
        decode_copy_bytes_per_tick(
            cfg, microbatch, num_cells,
            row_scatter=row_scatter, max_len=max_len,
        ),
        copy_bytes_per_second,
    )
    return chunking.optimal_schedule(
        work_per_item,
        devices,
        per_tick_overhead,
        max_chunks=max_chunks,
        per_tick_copy=per_tick_copy,
    )


def _overlay_combine(flow, src):
    """Entry-zip admission overlay: where ``gate`` is set, the slot's
    row is replaced wholesale by the admitted request's state (its
    prefill-sampled token, re-embedded hidden state, prompt length and
    budget) — the outgoing retired occupant simply stops re-entering."""
    gate = src["gate"]

    def sel(f, a):
        g = gate.reshape(gate.shape + (1,) * (f.ndim - 1))
        return jnp.where(g, a, f)

    out = dict(flow)
    for k in ("x", "tok", "pos", "active", "uid", "ngen", "budget"):
        out[k] = sel(flow[k], src[k])
    return out


class StreamEngine(_EngineBase):
    """Decode as a pipelined ``Stream.feedback`` program.

    One round = ``round_steps`` decode steps of all ``microbatches``
    in-flight items, executed as a single device program: items flow
    through ``num_cells`` layer-group cells, the emit (final-norm →
    logits → sample → re-embed) feeds each item's token back in with lag
    ``microbatches``, and admissions planned at round start (free slots,
    plus slots whose budget provably retires mid-round) are installed by
    the cells themselves the tick they first see the admission's item.
    With ``mesh=None`` the same program runs under ``LazyEvaluator`` —
    stream-shaped but layer-sequential, the pipelining ablation.
    """

    def __init__(
        self,
        params,
        cfg: ArchConfig,
        scfg: ServeConfig,
        pcfg: DecodePipelineConfig | None = None,
        mesh: jax.sharding.Mesh | None = None,
    ):
        super().__init__(params, cfg, scfg)
        pcfg = pcfg or DecodePipelineConfig()
        self.pcfg = pcfg
        if scfg.max_batch % pcfg.microbatches != 0:
            raise ValueError(
                f"max_batch={scfg.max_batch} not divisible by "
                f"microbatches={pcfg.microbatches}"
            )
        if pcfg.admit_per_round < 1:
            raise ValueError(
                "admit_per_round must be >= 1 (with 0 no request could "
                "ever enter a slot and run_until_drained would spin)"
            )
        self.mb_size = scfg.max_batch // pcfg.microbatches
        groups = cfg.num_layers // T.effective_period(cfg)
        if groups % pcfg.num_cells != 0:
            raise ValueError(
                f"{groups} layer groups not divisible by "
                f"num_cells={pcfg.num_cells}"
            )
        if mesh is None:
            self.evaluator = LazyEvaluator()
        else:
            self.evaluator = FutureEvaluator(
                mesh,
                pcfg.axis_name,
                schedule=pcfg.schedule,
                interleave=pcfg.interleave,
            )
        # Read-only/mutable split: layer params ride the Stream's
        # const_state (scan xs, stage-sharded, never written back); the
        # per-cell cache shard is the only mutable state.
        self.cell_consts, self.cell_states = T.split_decode_cells(
            params, T.init_cache(cfg, scfg.max_batch, scfg.max_len),
            pcfg.num_cells,
        )
        # Kernel dispatch for the hot path: the pipeline knob overrides
        # the model knob; resolved once ("auto" -> backend) so cells and
        # emit agree.
        self.kernels = resolve_mode(
            cfg.kernels if pcfg.kernels is None else pcfg.kernels
        )
        self.degraded = False
        if self.kernels == "pallas":
            # Probe the fused-kernel dispatch up front: an import-level
            # failure degrades here, before any request is accepted.
            try:
                from repro.kernels import get_impl

                get_impl("decode_attention", "pallas")
                get_impl("emit_norm_logits", "pallas")
            except Exception as e:  # noqa: BLE001
                self._degrade("kernel import failed", e)
        self._zero_single = T.init_cache(cfg, 1, scfg.max_len)
        self._embed = jax.jit(
            lambda toks: L.embed_lookup(params["embed"]["embedding"], toks)
        )
        self._by_uid: dict[int, Request] = {}
        self._build_programs()

    def _build_programs(self):
        """(Re)build the decode cells, emit, and jitted round under the
        current ``self.kernels`` mode.  Called at init and again by
        ``_degrade`` — the round must be re-jitted, not just re-pointed,
        since jit caches trace the old cell bodies."""
        cfg, scfg, pcfg = self.cfg, self.scfg, self.pcfg
        params = self.params
        self._cell_fn = T.make_decode_cell(
            cfg,
            num_cells=pcfg.num_cells,
            microbatch=self.mb_size,
            attn_impl=scfg.attn_impl,
            admissions=pcfg.admit_per_round,
            kernels=self.kernels,
        )
        self._emit = T.make_decode_emit(
            params, cfg,
            sample_fn=lambda lg, uid, ngen: sample_token(
                lg, scfg.temperature, scfg.seed, uid, ngen
            ),
            eos_id=scfg.eos_id,
            max_len=scfg.max_len,
            kernels=self.kernels,
        )
        t_, m_ = pcfg.round_steps, pcfg.microbatches

        def _round(cell_consts, cell_states, init_items, overlay_items):
            program = (
                Stream.feedback(init_items, t_ * m_, self._emit)
                .zip(Stream.source(overlay_items), _overlay_combine)
                .through(
                    self._cell_fn, cell_states, const_state=cell_consts
                )
            )
            res = program.collect(self.evaluator)
            return res.states[0], res.items

        # Donate the mutable cell states (the KV cache): the round's
        # output caches reuse the input buffers in place — the hot loop
        # allocates no second cache.  (CPU ignores donation; skip the
        # per-call warning there.)
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self._round = jax.jit(_round, donate_argnums=donate)

    def _degrade(self, reason: str, exc: Exception):
        """Fall back from the fused pallas path to the bitwise-identical
        xla path.  Served tokens are unchanged (the xla refs are the
        kernels' oracles); the event is logged, never swallowed."""
        self.degraded = True
        self.kernels = "xla"
        self.events.append({
            "event": "degraded", "from": "pallas", "to": "xla",
            "reason": reason, "error": f"{type(exc).__name__}: {exc}",
        })
        warnings.warn(
            f"StreamEngine degraded kernels=pallas -> xla ({reason}: "
            f"{type(exc).__name__}: {exc}); serving continues bit-identically",
            RuntimeWarning,
            stacklevel=3,
        )
        if hasattr(self, "_round"):  # runtime degrade: rebuild the round
            self._build_programs()

    @property
    def cache(self) -> PyTree:
        """The batch cache, re-merged from per-cell shards (inspection)."""
        return T.merge_decode_caches(self.cell_states)

    # -- round construction --------------------------------------------------

    def _plan_admissions(self, t_: int):
        """(slot, step, request) admissions for the coming round.

        Free slots admit at step 0.  A slot whose occupant provably
        exhausts its budget at round-local step k-1 is free at step k
        (EOS may free it earlier — admitting at k is then merely late,
        never wrong), so queued requests keep entering mid-flight.
        Requests that retire on their prefill-sampled token never occupy
        a slot.  Returns (admissions, finished_at_prefill).
        """
        import heapq

        a_max = self.pcfg.admit_per_round
        finished: list[Request] = []
        admissions: list[tuple[int, int, Request, PyTree]] = []
        events: list[tuple[int, int]] = []  # (step, slot), earliest first
        for slot, req in enumerate(self.active):
            if req is None:
                events.append((0, slot))
            else:
                k = req.max_new_tokens - len(req.out_tokens)
                if k < t_:
                    events.append((k, slot))
        heapq.heapify(events)
        while self.queue and len(admissions) < a_max and events:
            step, slot = heapq.heappop(events)
            while self.queue:
                req = self.queue.popleft()
                single, done = self._prefill_single(req)
                self._by_uid[req.uid] = req
                if done:
                    req.done = True
                    finished.append(req)
                    continue  # slot still free: try the next request
                admissions.append((slot, step, req, single))
                # This request may itself retire mid-round: its slot
                # frees again once its remaining budget is spent.
                k2 = step + (req.max_new_tokens - len(req.out_tokens))
                if k2 < t_:
                    heapq.heappush(events, (k2, slot))
                break
        return admissions, finished

    def _build_round_inputs(self, admissions):
        scfg, pcfg = self.scfg, self.pcfg
        b_, m_, t_ = scfg.max_batch, pcfg.microbatches, pcfg.round_steps
        bm = self.mb_size
        tok = np.zeros(b_, np.int32)
        active = np.zeros(b_, bool)
        uid = np.zeros(b_, np.int32)
        ngen = np.zeros(b_, np.int32)
        budget = np.ones(b_, np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok[slot] = req.out_tokens[-1]
            active[slot] = True
            uid[slot] = req.uid
            ngen[slot] = len(req.out_tokens)
            budget[slot] = req.max_new_tokens
        x = np.asarray(self._embed(jnp.asarray(tok)))[:, None, :]
        init_items = {
            "x": jnp.asarray(x.reshape(m_, bm, 1, -1)),
            "tok": jnp.asarray(tok.reshape(m_, bm)),
            "pos": jnp.asarray(self.lengths.reshape(m_, bm)),
            "active": jnp.asarray(active.reshape(m_, bm)),
            "uid": jnp.asarray(uid.reshape(m_, bm)),
            "ngen": jnp.asarray(ngen.reshape(m_, bm)),
            "budget": jnp.asarray(budget.reshape(m_, bm)),
            "mb": jnp.arange(m_, dtype=jnp.int32),
            "step": jnp.zeros(m_, jnp.int32),
        }

        n = t_ * m_
        ov = {
            "gate": np.zeros((n, bm), bool),
            "tok": np.zeros((n, bm), np.int32),
            "pos": np.zeros((n, bm), np.int32),
            "active": np.zeros((n, bm), bool),
            "uid": np.zeros((n, bm), np.int32),
            "ngen": np.zeros((n, bm), np.int32),
            "budget": np.ones((n, bm), np.int32),
        }
        singles, slots, steps, mbs = [], [], [], []
        for slot, step, req, single in admissions:
            mb, row = divmod(slot, bm)
            b = step * m_ + mb
            ov["gate"][b, row] = True
            ov["tok"][b, row] = req.out_tokens[-1]
            ov["pos"][b, row] = len(req.prompt)
            ov["active"][b, row] = True
            ov["uid"][b, row] = req.uid
            ov["ngen"][b, row] = len(req.out_tokens)
            ov["budget"][b, row] = req.max_new_tokens
            singles.append(single)
            slots.append(slot)
            steps.append(step)
            mbs.append(mb)
        # Pad the admission buffer to its static depth; step -1 never fires.
        while len(singles) < self.pcfg.admit_per_round:
            singles.append(self._zero_single)
            slots.append(0)
            steps.append(-1)
            mbs.append(-1)
        adm = T.stack_admission_payload(
            singles, slots, steps, mbs, self.pcfg.num_cells
        )
        # Embed only the gated rows (at most admit_per_round of them) —
        # everything else in the overlay is a zero the combine discards.
        ov_x = np.zeros((n, bm, 1, x.shape[-1]), x.dtype)
        gated = np.argwhere(ov["gate"])
        if len(gated):
            emb = np.asarray(
                self._embed(jnp.asarray(ov["tok"][gated[:, 0], gated[:, 1]]))
            )
            ov_x[gated[:, 0], gated[:, 1], 0] = emb
        overlay = {k: jnp.asarray(v) for k, v in ov.items()}
        overlay["x"] = jnp.asarray(ov_x)
        return init_items, overlay, adm

    # -- the round -----------------------------------------------------------

    def step(self) -> list[Request]:
        """One pipelined round of ``round_steps`` decode steps."""
        t_, m_ = self.pcfg.round_steps, self.pcfg.microbatches
        bm = self.mb_size
        finished = self._expire_deadlines()
        admissions, planned = self._plan_admissions(t_)
        finished.extend(planned)
        for slot, req in enumerate(self.active):
            if req is not None:
                self._by_uid[req.uid] = req
        if not admissions and all(r is None for r in self.active):
            return finished
        init_items, overlay, adm = self._build_round_inputs(admissions)
        # The admission payload is read-only within a round, so it rides
        # const_state — it never enters the mutable carry, and nothing
        # needs dropping afterwards (const state is not returned).
        try:
            new_states, collected = self._round(
                {**self.cell_consts, "adm": adm},
                self.cell_states, init_items, overlay,
            )
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:  # noqa: BLE001
            if self.kernels != "pallas":
                raise
            # Fused-kernel dispatch failed at trace/compile time:
            # degrade to the xla cells (bitwise-identical tokens) and
            # replay the identical round inputs.
            self._degrade("round dispatch failed", e)
            new_states, collected = self._round(
                {**self.cell_consts, "adm": adm},
                self.cell_states, init_items, overlay,
            )
        self.cell_states = new_states
        col = {
            k: np.asarray(collected[k])
            for k in ("tok", "pos", "active", "uid", "ngen")
        }
        # Walk emitted items in stream order; a row's token is real when
        # its ngen is one past what the host has — frozen (retired) rows
        # repeat their ngen and are skipped, exactly mirroring the emit.
        for b in range(t_ * m_):
            for r in range(bm):
                req = self._by_uid.get(int(col["uid"][b, r]))
                if req is None or req.done:
                    continue
                g = int(col["ngen"][b, r])
                if g != len(req.out_tokens) + 1:
                    continue
                tok = int(col["tok"][b, r])
                req.out_tokens.append(tok)
                done = (
                    g >= req.max_new_tokens
                    or tok == self.scfg.eos_id
                    or int(col["pos"][b, r]) + 1 >= self.scfg.max_len
                )
                if done:
                    req.done = True
                    finished.append(req)
        # Host slot state syncs from each microbatch's final item.
        for mb in range(m_):
            b = (t_ - 1) * m_ + mb
            for r in range(bm):
                slot = mb * bm + r
                self.lengths[slot] = int(col["pos"][b, r])
                req = self._by_uid.get(int(col["uid"][b, r]))
                live = bool(col["active"][b, r]) and req is not None and not req.done
                self.active[slot] = req if live else None
        self._by_uid = {
            r.uid: r for r in self.active if r is not None
        }
        return finished
