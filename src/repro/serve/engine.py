"""Serving engine: continuous batching over a slotted KV cache.

The engine is the paper's construct at the request level: each submitted
request returns a *future* (its completion), the decode loop is the
stream, and chunked prefill (``prefill_chunk``) is the §7 chunk-size knob
balancing time-to-first-token against decode-step latency.

Architecture:
  * ``max_batch`` cache slots; per-slot length/active/eos state on host.
  * admit: new requests prefill in chunks (B=1) and are scattered into a
    free slot's cache rows.
  * step: one batched ``decode_step`` over all slots (inactive slots are
    masked); sampled tokens append to per-slot buffers.
  * complete: slots retire on EOS or max_new_tokens; their futures resolve.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 1024
    prefill_chunk: int = 128
    max_new_tokens: int = 64
    eos_id: int = -1  # -1: never; run to max_new_tokens
    temperature: float = 0.0  # 0 => greedy
    attn_impl: str = "dense"
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, params, cfg: ArchConfig, scfg: ServeConfig):
        assert not cfg.embeds_input, "engine serves token-input archs"
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.cache = T.init_cache(cfg, scfg.max_batch, scfg.max_len)
        self.lengths = np.zeros(scfg.max_batch, np.int32)
        self.active: list[Request | None] = [None] * scfg.max_batch
        self.queue: deque[Request] = deque()
        self._uid = 0
        self._rng = np.random.default_rng(scfg.seed)

        self._decode = jax.jit(
            partial(
                T.decode_step, cfg=cfg, attn_impl=scfg.attn_impl,
            )
        )
        self._prefill = jax.jit(
            partial(
                T.prefill_step, cfg=cfg, attn_impl=scfg.attn_impl,
            ),
            static_argnames=(),
        )

    # -- public API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int | None = None) -> Request:
        """Returns the request handle (its .done flag is the future)."""
        req = Request(
            uid=self._uid,
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens or self.scfg.max_new_tokens,
        )
        self._uid += 1
        self.queue.append(req)
        return req

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self.queue and all(r is None for r in self.active):
                break
        return finished

    # -- internals -----------------------------------------------------------

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.popleft()
            self._prefill_into_slot(req, slot)

    def _prefill_into_slot(self, req: Request, slot: int):
        ck = self.scfg.prefill_chunk
        prompt = req.prompt
        plen = len(prompt)
        full = (plen // ck) * ck
        single = T.init_cache(self.cfg, 1, self.scfg.max_len)
        logits = None
        for c in range(full // ck):
            chunk = jnp.asarray(prompt[None, c * ck : (c + 1) * ck])
            logits, single = self._prefill(
                self.params, single, tokens=chunk, pos=c * ck
            )
        # Tail tokens (plen % chunk) stream through single decode steps.
        for t in range(full, plen):
            logits, single = self._decode(
                self.params, single,
                tokens=jnp.asarray(prompt[None, t]),
                lengths=jnp.full((1,), t, jnp.int32),
            )
        # Scatter this request's cache rows into the batch cache at `slot`.
        def insert(batch_leaf, single_leaf):
            return batch_leaf.at[:, slot].set(single_leaf[:, 0])

        self.cache = jax.tree.map(insert, self.cache, single)
        self.lengths[slot] = plen
        self.active[slot] = req
        tok = self._sample(np.asarray(logits)[0])
        req.out_tokens.append(int(tok))

    def _sample(self, logits: np.ndarray) -> int:
        if self.scfg.temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp(logits / self.scfg.temperature - np.max(logits))
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    def step(self) -> list[Request]:
        """Admit, one batched decode step, retire. Returns newly finished."""
        self._admit()
        slots = [i for i, r in enumerate(self.active) if r is not None]
        if not slots:
            return []
        # last token per active slot (prompt end or last generated)
        tokens = np.zeros(self.scfg.max_batch, np.int32)
        for i in slots:
            req = self.active[i]
            tokens[i] = req.out_tokens[-1] if req.out_tokens else req.prompt[-1]
        logits, self.cache = self._decode(
            self.params, self.cache,
            tokens=jnp.asarray(tokens),
            lengths=jnp.asarray(self.lengths),
        )
        logits = np.asarray(logits)
        finished = []
        for i in slots:
            req = self.active[i]
            self.lengths[i] += 1
            tok = self._sample(logits[i])
            req.out_tokens.append(tok)
            hit_eos = tok == self.scfg.eos_id
            full = self.lengths[i] + 1 >= self.scfg.max_len
            if len(req.out_tokens) >= req.max_new_tokens or hit_eos or full:
                req.done = True
                finished.append(req)
                self.active[i] = None
        return finished
