"""Training step: loss, microbatch gradient accumulation, optimizer update.

The microbatch axis is a bounded stream (the paper's chunking knob): under
plain accumulation it is evaluated Lazily (sequential scan, constant
memory); under the pipeline config the same microbatches flow through
layer stages on the ``pod`` axis (Future) under a pluggable schedule
(``pipeline_schedule``: gpipe / one_f_one_b / interleaved — see
:mod:`repro.core.schedules`).  ``num_microbatches`` trades activation
memory against fill/drain bubble per
:func:`repro.core.chunking.optimal_schedule`, which picks the
(schedule, M) pair jointly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.chunking import chunk_axis
from repro.models import transformer as T
from repro.train import optimizer as O

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    num_microbatches: int = 1
    accum_dtype: Any = jnp.float32  # bf16 for >=100B configs
    remat: bool = True
    unroll: bool = False  # unroll scans (dry-run: exact HLO flop counts)
    attn_impl: str = "chunked"
    q_chunk: int = 512
    kv_chunk: int = 1024
    causal_skip: bool | None = None  # None = auto (§Perf iteration 6)
    z_loss_coef: float = 1e-4
    moe_lb_coef: float = 1e-2
    moe_z_coef: float = 1e-3
    # Layer-pipeline mode (stream-future over the pod axis): the tick
    # schedule the FutureEvaluator executes and, for "interleaved", how
    # many non-contiguous stage groups each device owns.
    pipeline_schedule: str = "gpipe"
    pipeline_interleave: int = 1
    # Backward execution: "autodiff" (jax.grad transposes the forward
    # plan) or "planned" (the combined plan's B units run as scheduled
    # work through a custom VJP — true 1F1B, min(S, M) stash at the
    # plan level; gradients bitwise-equal).  See configs.base.
    pipeline_backward: str = "autodiff"
    # Kernel dispatch (repro.kernels).  Training currently requires
    # "xla": the Pallas kernels have no VJPs wired, so "pallas" is
    # rejected up front (see make_train_step) instead of failing deep in
    # jax.grad; "auto" resolves to "xla" on every backend here.
    kernels: str = "xla"

    def pipeline_config(
        self, num_stages: int, axis_name: str = "pod"
    ) -> "PipelineConfig":
        """The PipelineConfig this training config implies for a stage count."""
        from repro.core.pipeline import PipelineConfig

        return PipelineConfig(
            num_stages=num_stages,
            num_microbatches=self.num_microbatches,
            axis_name=axis_name,
            remat=self.remat,
            schedule=self.pipeline_schedule,
            interleave=self.pipeline_interleave,
            backward=self.pipeline_backward,
        )


def lm_loss(params, cfg: ArchConfig, batch: PyTree, tcfg: TrainConfig):
    """Next-token CE (fp32 logits, logsumexp form) + z-loss + MoE aux."""
    kw = {}
    if cfg.embeds_input:
        kw["embeds"] = batch["embeds"]
    else:
        kw["tokens"] = batch["tokens"]
    if cfg.vision_tokens:
        kw["vision_embeds"] = batch["vision_embeds"]
    logits, _, aux = T.forward(
        params, cfg,
        attn_impl=tcfg.attn_impl, q_chunk=tcfg.q_chunk, kv_chunk=tcfg.kv_chunk,
        causal_skip=tcfg.causal_skip,
        remat=tcfg.remat, unroll=True if tcfg.unroll else 1, **kw,
    )
    labels = batch["labels"]  # (B, S)
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B,S)
    # gold logit via masked sum (partitions over a vocab-sharded logits
    # axis; take_along_axis would gather across shards)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    gold = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    ce = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(ce) / denom
    z_loss = jnp.sum(jnp.square(lse) * mask) / denom
    total = loss + tcfg.z_loss_coef * z_loss
    if cfg.moe is not None:
        total = (
            total
            + tcfg.moe_lb_coef * aux["moe_lb_loss"]
            + tcfg.moe_z_coef * aux["moe_z_loss"]
        )
    metrics = {"loss": loss, "z_loss": z_loss, **aux}
    return total, metrics


def accumulate_grads(
    params, cfg: ArchConfig, batch: PyTree, tcfg: TrainConfig,
    param_pspecs: PyTree | None = None,
):
    """Scan microbatches, accumulating grads in ``accum_dtype``."""
    grad_fn = jax.value_and_grad(lm_loss, has_aux=True)
    if tcfg.num_microbatches == 1:
        (_, metrics), grads = grad_fn(params, cfg, batch, tcfg)
        return grads, metrics

    micro = chunk_axis(batch, tcfg.num_microbatches)

    def step(carry, mb):
        acc, metrics_acc = carry
        (_, metrics), grads = grad_fn(params, cfg, mb, tcfg)
        if param_pspecs is not None:
            # Constrain the raw per-microbatch grads BEFORE the add: the
            # data-axis reduction then lowers to a reduce-scatter onto the
            # FSDP shard (1× bytes) instead of an all-reduce of the full
            # gradient (2×) followed by slicing.  §Perf iteration 1.
            from repro.parallel.sharding import maybe_constrain
            grads = jax.tree.map(maybe_constrain, grads, param_pspecs)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(tcfg.accum_dtype), acc, grads
        )
        if param_pspecs is not None:
            from repro.parallel.sharding import maybe_constrain
            acc = jax.tree.map(maybe_constrain, acc, param_pspecs)
        metrics_acc = jax.tree.map(lambda a, m: a + m, metrics_acc, metrics)
        return (acc, metrics_acc), None

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params
    )
    if param_pspecs is not None:
        from repro.parallel.sharding import maybe_constrain
        zeros = jax.tree.map(maybe_constrain, zeros, param_pspecs)
    metrics0 = {
        "loss": 0.0, "z_loss": 0.0,
        "moe_lb_loss": 0.0, "moe_z_loss": 0.0, "moe_drop_fraction": 0.0,
    }
    metrics0 = {k: jnp.zeros((), jnp.float32) for k in metrics0}
    (grads, metrics), _ = lax.scan(
        step, (zeros, metrics0), micro,
        unroll=tcfg.num_microbatches if tcfg.unroll else 1,
    )
    inv = 1.0 / tcfg.num_microbatches
    return (
        jax.tree.map(lambda g: g * inv, grads),
        jax.tree.map(lambda m: m * inv, metrics),
    )


def make_train_step(
    cfg: ArchConfig, tcfg: TrainConfig, ocfg: O.AdamWConfig,
    param_pspecs: PyTree | None = None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    from repro.kernels import KERNEL_MODES

    if tcfg.kernels not in KERNEL_MODES:
        raise ValueError(
            f"kernels={tcfg.kernels!r}; expected one of {KERNEL_MODES}"
        )
    if tcfg.kernels == "pallas":
        if tcfg.pipeline_backward == "planned":
            raise ValueError(
                "kernels='pallas' is not supported with "
                "pipeline_backward='planned': the planned backward replays "
                "forward units through their custom VJP, and the Pallas "
                "kernels have no VJPs wired yet.  Use kernels='xla' (or "
                "'auto', which resolves to xla for training)."
            )
        raise ValueError(
            "kernels='pallas' is not supported for training: the Pallas "
            "kernels have no VJPs wired, so jax.grad cannot transpose "
            "them.  Use kernels='xla' (or 'auto', which resolves to xla "
            "for training); pallas dispatch is a serving-path knob."
        )

    def train_step(params, opt_state, batch):
        grads, metrics = accumulate_grads(params, cfg, batch, tcfg, param_pspecs)
        params, opt_state, opt_metrics = O.adamw_update(
            params, grads, opt_state, cfg=ocfg
        )
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step
