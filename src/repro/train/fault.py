"""Fault tolerance: checkpoint/restart, heartbeats, straggler mitigation.

``ResilientLoop`` wraps the jitted train step with the runbook a 1000+
node fleet needs, built on the shared :mod:`repro.resilience` package
(the same machinery the serving supervisor consumes — see
:mod:`repro.serve.supervisor`):

* **checkpoint/restart** — periodic async checkpoints; on any step
  exception the loop restores the latest checkpoint and replays.  The
  data pipeline is step-keyed (deterministic PRNG per step), so replayed
  steps see identical batches — restart is bitwise reproducible, and
  ``history`` records each step exactly once (replayed entries are
  truncated back to the restored step on restart).
* **heartbeats** — a monotonic per-step heartbeat file
  (:class:`repro.resilience.Heartbeat`); an external supervisor (or the
  test suite) detects a wedged worker by heartbeat age and SIGKILLs it,
  landing in the restart path above.
* **straggler mitigation** — per-step wall times feed an EMA
  (:class:`repro.resilience.StragglerTracker`); steps slower than
  ``straggler_factor``× the EMA are counted and surfaced.  On a real
  pod the action is to cordon the slow host and re-shard (see
  :mod:`repro.train.elastic`); here the detector + policy hook are real
  and the cordon action is a callback.
* **preemption windows** — ``request_stop()`` (SIGTERM handler) finishes
  the current step, writes a final checkpoint, and exits cleanly.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax

from repro.resilience import Heartbeat, RestartBudget, RestartPolicy, StragglerTracker
from repro.resilience.injection import call_injector
from repro.train.checkpoint import Checkpointer

PyTree = Any


@dataclasses.dataclass
class FaultConfig:
    checkpoint_every: int = 50
    heartbeat_path: str | None = None
    straggler_factor: float = 2.0
    straggler_ema: float = 0.9
    max_restarts: int = 3
    backoff_seconds: float = 0.0  # restart backoff; 0 = immediate replay


class ResilientLoop:
    def __init__(
        self,
        step_fn: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree, dict]],
        checkpointer: Checkpointer,
        fault_cfg: FaultConfig,
        on_straggler: Callable[[int, float], None] | None = None,
    ):
        self.step_fn = step_fn
        self.ckpt = checkpointer
        self.cfg = fault_cfg
        self.on_straggler = on_straggler
        self._stop = False
        self._hb = Heartbeat(fault_cfg.heartbeat_path)
        self._straggler = StragglerTracker(
            fault_cfg.straggler_factor, fault_cfg.straggler_ema, on_straggler
        )
        self.stats = {"restarts": 0, "stragglers": 0, "steps": 0}

    def request_stop(self, *_):
        self._stop = True

    def install_signal_handlers(self):
        signal.signal(signal.SIGTERM, self.request_stop)

    def _heartbeat(self, step: int):
        self._hb.beat(step)

    def _track_time(self, step: int, dt: float):
        if self._straggler.observe(step, dt):
            self.stats["stragglers"] += 1

    def run(
        self,
        params: PyTree,
        opt_state: PyTree,
        batch_fn: Callable[[int], PyTree],
        num_steps: int,
        start_step: int = 0,
        fail_injector: Callable[[int], None] | None = None,
    ) -> tuple[PyTree, PyTree, int, list[dict]]:
        """Run to ``num_steps`` with restart-on-failure.  Returns final state."""
        step = start_step
        history: list[dict] = []
        budget = RestartBudget(RestartPolicy(
            max_restarts=self.cfg.max_restarts,
            backoff_seconds=self.cfg.backoff_seconds,
        ))
        # Restart-from-nothing must replay from the *initial* state, not
        # whatever the params had mutated to when the step blew up.
        init_params, init_opt_state = params, opt_state
        while step < num_steps and not self._stop:
            try:
                call_injector(fail_injector, step, self)
                batch = batch_fn(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch
                )
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self._track_time(step, dt)
                self._heartbeat(step)
                history.append(
                    {"step": step, **{k: float(v) for k, v in metrics.items()}}
                )
                step += 1
                self.stats["steps"] += 1
                if step % self.cfg.checkpoint_every == 0 or step == num_steps:
                    self.ckpt.save(
                        step, {"params": params, "opt_state": opt_state}
                    )
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                if not budget.admit():
                    raise
                self.stats["restarts"] += 1
                time.sleep(budget.next_delay())
                restored_step = self.ckpt.latest_step()
                if restored_step is None:
                    # No checkpoint yet: restart from the initial state.
                    params, opt_state = init_params, init_opt_state
                    step = start_step
                else:
                    state, step = self.ckpt.restore(
                        {"params": params, "opt_state": opt_state}
                    )
                    params, opt_state = state["params"], state["opt_state"]
                # The replay will re-run steps >= the restored step: drop
                # their history entries so each step is recorded exactly
                # once and stats["steps"] counts completed steps, not
                # completed-plus-replayed.
                kept = [h for h in history if h["step"] < step]
                self.stats["steps"] -= len(history) - len(kept)
                history[:] = kept
        self.ckpt.save(step, {"params": params, "opt_state": opt_state})
        self.ckpt.wait()
        return params, opt_state, step, history
