"""Fault tolerance: checkpoint/restart, heartbeats, straggler mitigation.

``ResilientLoop`` wraps the jitted train step with the runbook a 1000+
node fleet needs:

* **checkpoint/restart** — periodic async checkpoints; on any step
  exception the loop restores the latest checkpoint and replays.  The
  data pipeline is step-keyed (deterministic PRNG per step), so replayed
  steps see identical batches — restart is bitwise reproducible.
* **heartbeats** — a monotonic per-step heartbeat file; an external
  supervisor (or the test suite) detects a wedged worker by heartbeat age
  and SIGKILLs it, landing in the restart path above.
* **straggler mitigation** — per-step wall times feed an EMA; steps slower
  than ``straggler_factor``× the EMA are counted and surfaced.  On a real
  pod the action is to cordon the slow host and re-shard (see
  :mod:`repro.train.elastic`); here the detector + policy hook are real
  and the cordon action is a callback.
* **preemption windows** — ``request_stop()`` (SIGTERM handler) finishes
  the current step, writes a final checkpoint, and exits cleanly.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable

import jax

from repro.train.checkpoint import Checkpointer

PyTree = Any


@dataclasses.dataclass
class FaultConfig:
    checkpoint_every: int = 50
    heartbeat_path: str | None = None
    straggler_factor: float = 2.0
    straggler_ema: float = 0.9
    max_restarts: int = 3


class ResilientLoop:
    def __init__(
        self,
        step_fn: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree, dict]],
        checkpointer: Checkpointer,
        fault_cfg: FaultConfig,
        on_straggler: Callable[[int, float], None] | None = None,
    ):
        self.step_fn = step_fn
        self.ckpt = checkpointer
        self.cfg = fault_cfg
        self.on_straggler = on_straggler
        self._stop = False
        self._ema_step_time: float | None = None
        self.stats = {"restarts": 0, "stragglers": 0, "steps": 0}

    def request_stop(self, *_):
        self._stop = True

    def install_signal_handlers(self):
        signal.signal(signal.SIGTERM, self.request_stop)

    def _heartbeat(self, step: int):
        if self.cfg.heartbeat_path:
            with open(self.cfg.heartbeat_path, "w") as f:
                f.write(f"{step} {time.time()}\n")

    def _track_time(self, step: int, dt: float):
        if self._ema_step_time is None:
            self._ema_step_time = dt
            return
        if dt > self.cfg.straggler_factor * self._ema_step_time:
            self.stats["stragglers"] += 1
            if self.on_straggler:
                self.on_straggler(step, dt / self._ema_step_time)
        a = self.cfg.straggler_ema
        self._ema_step_time = a * self._ema_step_time + (1 - a) * dt

    def run(
        self,
        params: PyTree,
        opt_state: PyTree,
        batch_fn: Callable[[int], PyTree],
        num_steps: int,
        start_step: int = 0,
        fail_injector: Callable[[int], None] | None = None,
    ) -> tuple[PyTree, PyTree, int, list[dict]]:
        """Run to ``num_steps`` with restart-on-failure.  Returns final state."""
        step = start_step
        history: list[dict] = []
        restarts_left = self.cfg.max_restarts
        # Restart-from-nothing must replay from the *initial* state, not
        # whatever the params had mutated to when the step blew up.
        init_params, init_opt_state = params, opt_state
        while step < num_steps and not self._stop:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                batch = batch_fn(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch
                )
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self._track_time(step, dt)
                self._heartbeat(step)
                history.append(
                    {"step": step, **{k: float(v) for k, v in metrics.items()}}
                )
                step += 1
                self.stats["steps"] += 1
                if step % self.cfg.checkpoint_every == 0 or step == num_steps:
                    self.ckpt.save(
                        step, {"params": params, "opt_state": opt_state}
                    )
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                if restarts_left <= 0:
                    raise
                restarts_left -= 1
                self.stats["restarts"] += 1
                restored_step = self.ckpt.latest_step()
                if restored_step is None:
                    # No checkpoint yet: restart from the initial state.
                    params, opt_state = init_params, init_opt_state
                    step = start_step
                    continue
                state, step = self.ckpt.restore(
                    {"params": params, "opt_state": opt_state}
                )
                params, opt_state = state["params"], state["opt_state"]
        self.ckpt.save(step, {"params": params, "opt_state": opt_state})
        self.ckpt.wait()
        return params, opt_state, step, history
