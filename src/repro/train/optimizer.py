"""AdamW with dtype-configurable moments and global-norm clipping.

The ≥100 B configs cannot hold fp32 Adam moments on 16 GB/chip even fully
sharded (398 B × 8 B/param / 256 chips = 12.4 GB for m+v alone), so moments
are stored in a configurable dtype — bf16 by default for huge models —
with fp32 math at update time.  This is the "optimizer state compression"
leg of the distributed-optimization tricks (DESIGN §3); gradient
compression for the cross-pod all-reduce lives in
:mod:`repro.train.compression`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32  # bf16 for >=100B configs
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def init_opt_state(params: PyTree, cfg: AdamWConfig) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params: PyTree, cfg: AdamWConfig) -> PyTree:
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, abstract_params),
        "v": jax.tree.map(zeros, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lr_schedule(step, cfg: AdamWConfig):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    progress = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * progress))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * jnp.minimum(warm, decayed)


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)
        )
    )


def adamw_update(
    params: PyTree, grads: PyTree, opt_state: PyTree, cfg: AdamWConfig
) -> tuple[PyTree, PyTree, dict]:
    """One update; fp32 math, params/moments cast back to storage dtypes."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def update_one(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [update_one(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    metrics = {"grad_norm": gnorm, "learning_rate": lr}
    return new_params, new_state, metrics
