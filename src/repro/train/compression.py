"""Gradient compression for slow links (cross-pod all-reduce).

The pod axis of the production mesh rides inter-pod links (~an order of
magnitude slower than intra-pod ICI).  Two standard tricks, both applied
only to the *pod-axis* reduction:

* **bf16 reduction** — gradients are cast to bf16 before the cross-pod
  all-reduce and the *local* error (the cast residual) is fed back into
  the next step's gradient (error feedback), keeping the update unbiased
  over time [Seide et al. 2014-style EF].
* **moment-dtype compression** lives in :mod:`repro.train.optimizer`.

Under pure pjit the collective is implicit, so compression is expressed by
casting at the accumulation boundary; with explicit ``shard_map`` pipelines
the cast wraps the ``psum`` itself.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def compress_decompress(grads: PyTree, error: PyTree | None, dtype=jnp.bfloat16):
    """Cast-with-error-feedback.  Returns (compressed_f32, new_error).

    grads are fp32; ``error`` is the residual carried from the previous
    step (same structure, fp32), or None on step 0.
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q = corrected.astype(dtype)
        new_e = corrected - q.astype(jnp.float32)
        return q.astype(jnp.float32), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )


def init_error_state(abstract_grads: PyTree) -> PyTree:
    return jax.tree.map(
        lambda g: jax.ShapeDtypeStruct(g.shape, jnp.float32), abstract_grads
    )
