"""Sharded checkpointing with asynchronous (future) writes.

Layout: ``<dir>/step_<N>/{manifest.json, arrays.npz}`` written atomically
(tmp dir + rename) so a crash mid-write never corrupts the latest
checkpoint — the restore path simply picks the newest complete manifest.
Writes happen on a host future (:class:`repro.core.future.HostFuture`):
the train loop queues the device→host copy and keeps stepping — the
paper's future-tail applied to I/O.  ``wait()`` is the Await.result before
exit; at most one write is in flight (back-pressure).

On a real multi-host pod each process writes its own shard files keyed by
``jax.process_index()``; this container is single-process, and the layout
carries the process key so the multi-host path is the same code.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Any

import jax
import numpy as np

from repro.core.future import HostFuture

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._inflight: HostFuture | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: PyTree, blocking: bool = False):
        """Queue an async write of ``state`` at ``step``."""
        self.wait()  # back-pressure: one in flight
        # Device->host copy happens now (so the train loop can mutate state);
        # file I/O happens on the future.
        host_state = jax.tree.map(np.asarray, jax.device_get(state))

        def write():
            self._write_sync(step, host_state)
            return step

        self._inflight = HostFuture(write)
        if blocking:
            self.wait()

    def _write_sync(self, step: int, host_state: PyTree):
        proc = jax.process_index()
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + f".tmp{proc}"
        os.makedirs(tmp, exist_ok=True)
        arrays = dict(_flatten_with_paths(host_state))
        np.savez(os.path.join(tmp, f"arrays_p{proc}.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "process": proc,
            "num_arrays": len(arrays),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._inflight is not None:
            self._inflight.force()
            self._inflight = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            # A crash mid-write leaves a stale ``step_N.tmpP`` dir for
            # whatever process index P was writing — only exact
            # ``step_<digits>`` names are complete checkpoints.
            if not re.fullmatch(r"step_\d+", name):
                continue
            path = os.path.join(self.directory, name, "manifest.json")
            if os.path.exists(path):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: int | None = None) -> tuple[PyTree, int]:
        """Restore into the structure (and shardings) of ``template``.

        ``template`` leaves may be arrays or ShapeDtypeStructs with
        ``.sharding`` set; restored arrays are device_put accordingly.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        proc = jax.process_index()
        path = os.path.join(
            self.directory, f"step_{step:08d}", f"arrays_p{proc}.npz"
        )
        arrays = np.load(path)
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for keypath, leaf in flat:
            key = jax.tree_util.keystr(keypath)
            if key not in arrays:
                raise KeyError(f"checkpoint missing {key}")
            value = arrays[key]
            if hasattr(leaf, "sharding") and leaf.sharding is not None:
                value = jax.device_put(value, leaf.sharding)
            else:
                value = jax.device_put(value)
            if value.dtype != leaf.dtype:
                value = value.astype(leaf.dtype)
            leaves.append(value)
        return jax.tree_util.tree_unflatten(treedef, leaves), step
