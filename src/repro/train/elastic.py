"""Elastic scaling: re-mesh and re-shard on device-count change.

When a pod is cordoned (hardware fault) or capacity is added, the job
resumes on a different device count.  Because checkpoints are stored as
logical (unsharded) arrays and shardings are *derived* from the mesh via
the logical-axis rules, elasticity is: build the new mesh → derive new
shardings → device_put the restored state.  No resharding code is specific
to any topology.

``choose_mesh_shape`` picks the largest (data, model) factorization that
(a) keeps ``model`` a divisor of the preferred TP width and (b) uses every
remaining device for data parallelism; global batch is kept constant by
adjusting ``num_microbatches`` (the stream chunk count — the paper's knob
again) so per-device microbatch size stays fixed.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.parallel.sharding import param_shardings


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    num_microbatches: int


def choose_mesh_shape(
    num_devices: int, preferred_model: int = 16, global_batch: int = 256,
    per_device_micro_tokens: int | None = None,
) -> ElasticPlan:
    model = preferred_model
    while model > 1 and num_devices % model != 0:
        model //= 2
    data = num_devices // model
    # Keep per-device microbatch constant: more data shards => fewer chunks.
    num_micro = max(1, global_batch // max(data, 1) // 4)
    # num_microbatches must divide the global batch.
    while global_batch % (num_micro) != 0:
        num_micro -= 1
    return ElasticPlan((data, model), ("data", "model"), num_micro)


def remesh_state(state, layout, rules, new_mesh):
    """Re-shard a (restored) state pytree onto a new mesh."""
    shardings = param_shardings(layout, rules, new_mesh)
    return jax.tree.map(jax.device_put, state, shardings)
