"""Elastic scaling: re-mesh, re-plan the schedule, re-shard on change.

When a pod is cordoned (hardware fault) or capacity is added, the job
resumes on a different device count.  Because checkpoints are stored as
logical (unsharded) arrays and shardings are *derived* from the mesh via
the logical-axis rules, elasticity is: build the new mesh → derive new
shardings → device_put the restored state.  No resharding code is specific
to any topology.

``choose_mesh_shape`` picks the largest (data, model) factorization that
(a) keeps ``model`` a divisor of the preferred TP width and (b) uses every
remaining device for data parallelism; global batch is kept constant by
adjusting ``num_microbatches`` (the stream chunk count — the paper's knob
again) so per-device microbatch size stays fixed.

``choose_elastic_plan`` goes further for pipelined jobs: the pipeline
schedule is **mesh-shape-dependent** — schedule, M and V all move with
the pipeline axis size (a deep pipeline wants interleaving to cut the
fill/drain bubble; a shallow one wants plain fill/drain with cheap
ticks) — so on node loss it re-runs
:func:`repro.core.chunking.optimal_schedule` against the shrunken axis
instead of only re-deriving the mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import chunking
from repro.core.chunking import ScheduleChoice
from repro.parallel.sharding import param_shardings


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    num_microbatches: int
    # Joint (schedule, M, V) re-plan for the pipeline axis; None when the
    # job is not pipelined (pipeline axis of 1).
    schedule: ScheduleChoice | None = None


def choose_mesh_shape(
    num_devices: int, preferred_model: int = 16, global_batch: int = 256,
    per_device_micro_tokens: int | None = None,
) -> ElasticPlan:
    model = preferred_model
    while model > 1 and num_devices % model != 0:
        model //= 2
    data = num_devices // model
    # Keep per-device microbatch constant: more data shards => fewer chunks.
    num_micro = max(1, global_batch // max(data, 1) // 4)
    # num_microbatches must divide the global batch.
    while global_batch % (num_micro) != 0:
        num_micro -= 1
    return ElasticPlan((data, model), ("data", "model"), num_micro)


def choose_elastic_plan(
    num_devices: int,
    *,
    preferred_model: int = 16,
    preferred_pipeline: int = 1,
    global_batch: int = 256,
    work_per_item: float = 1.0,
    per_tick_overhead: float = 1e-4,
    memory_budget_items: float | None = None,
    num_sources: int = 1,
    backward: str = "autodiff",
) -> ElasticPlan:
    """Mesh factorization *and* schedule re-plan for the new device count.

    The pipeline axis shrinks to the largest power-of-two divisor of
    ``num_devices`` at most ``preferred_pipeline``; the remaining devices
    factor into (data, model) as :func:`choose_mesh_shape` does.  With a
    pipeline axis > 1 the (schedule, M, V) triple is re-derived by
    :func:`repro.core.chunking.optimal_schedule` — on a pod loss the
    optimum genuinely moves (e.g. a deep pipeline's interleaved schedule
    degrades to plain fill/drain when the axis halves), so re-deriving
    only the mesh silently runs the wrong schedule.  ``num_sources``
    forwards multi-injection feed costs into the memory budget;
    ``backward`` scores the stash for the job's backward mode and
    defaults to ``"autodiff"`` — matching ``TrainConfig``'s default —
    because a job training with the autodiff backward cannot buy memory
    with 1F1B, and the budget check must not pretend it can.  Pass
    ``backward="planned"`` (with ``pipeline_backward="planned"``) to
    let the re-plan use the combined plans' schedule-level stash
    bounds (see :class:`repro.core.schedules.CombinedPlan` for what
    the two-phase realization holds at the autodiff phase boundary).
    """
    pipe = 1
    while pipe * 2 <= preferred_pipeline and num_devices % (pipe * 2) == 0:
        pipe *= 2
    rest = num_devices // pipe
    base = choose_mesh_shape(rest, preferred_model, global_batch)
    if pipe <= 1:
        return ElasticPlan(
            base.mesh_shape + (1,),
            base.axis_names + ("pipe",),
            base.num_microbatches,
            schedule=None,
        )
    # M is constrained to divide the global batch *inside* the search, so
    # the returned choice's modeled time and budget check describe the M
    # the plan actually runs.
    choice = chunking.optimal_schedule(
        work_per_item,
        pipe,
        per_tick_overhead,
        max_chunks=global_batch,
        memory_budget_items=memory_budget_items,
        num_sources=num_sources,
        chunks_divide=global_batch,
        backward=backward,
    )
    return ElasticPlan(
        base.mesh_shape + (pipe,),
        base.axis_names + ("pipe",),
        choice.num_chunks,
        schedule=choice,
    )


def remesh_state(state, layout, rules, new_mesh):
    """Re-shard a (restored) state pytree onto a new mesh."""
    shardings = param_shardings(layout, rules, new_mesh)
    return jax.tree.map(jax.device_put, state, shardings)
