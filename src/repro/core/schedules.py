"""Pipeline schedules as data: per-tick (stage, microbatch, group) plans.

The Future evaluator (:mod:`repro.core.stream`) is a plan *executor*: it
runs a ``lax.scan`` whose per-tick behaviour — which microbatch each
device works on, which of its local cell groups it applies, where its
input comes from (fresh injection vs. a received in-flight buffer slot),
and whether its output is a final result — is read from host-built int32
tables.  A :class:`SchedulePlan` is those tables plus the buffer-slot and
item-feed bookkeeping the executor needs.  Building plans on the host
keeps the device program schedule-oblivious: new schedules are new table
builders, not new evaluators.

Three schedules ship:

``gpipe``
    Fill/drain.  Stage ``s`` runs microbatch ``m`` at tick
    ``h*s + m`` where ``h`` is the hand-off latency (2 for the
    issue-early/force-late ring used by the evaluator).  Peak in-flight
    activation stash under autodiff training: all ``M`` microbatches.

``one_f_one_b``
    1F1B.  The *executed forward* plan is tick-identical to GPipe (the
    backward is derived by ``jax.grad``, which reverses the forward
    scan; true interleaved F/B execution would need a hand-written VJP
    pipeline — an open item).  What differs is the modeled training
    schedule: steady-state activation stash is ``min(S, M)``
    microbatches instead of ``M``, which is what
    :func:`repro.core.chunking.optimal_schedule` uses to admit larger
    ``M`` under a memory budget.

``interleaved``
    Each device owns ``V`` non-contiguous cell groups (virtual stages;
    global virtual stage ``p`` lives on device ``p % D``).  Per-tick
    work shrinks by ``V`` while the fill/drain tick count stays
    ``h*(D-1)``, cutting the bubble from ``h(D-1)/(M + h(D-1))`` to
    ``h(D-1)/(V*M + h(D-1))`` — Megatron-style interleaving expressed
    as a stream-of-futures plan.  The hand-off stays a single ring
    ``ppermute`` because consecutive virtual stages always sit on
    ring-adjacent devices (``p+1`` lives on ``(d+1) % D``).

Plans are built by a greedy list scheduler (priority: lowest microbatch,
then deepest virtual stage) under two constraints: a device runs one
unit per tick, and unit ``(p, m)`` may start ``handoff`` ticks after
``(p-1, m)`` finished.  For ``M >= D`` this achieves the closed-form
tick counts above; the plan's own ``num_ticks``/``bubble_fraction`` are
always the ground truth (and are tested against the analytic model).

**Feedback (persistent) plans** — ``feedback_lag=L`` adds the unfold
combinator's dependency: item ``b``'s entry unit ``(0, b)`` (for
``b >= L``) becomes ready only ``handoff`` ticks after the *last*
virtual stage finished item ``b - L``.  Only the first ``L`` items are
fed from the primary source's carousel; every later item re-enters from
its own output, carried by the same one-hop ring (the last virtual
stage always lives on device D-1, whose ring successor is device 0) and
parked in the same interval-colored in-flight buffers until its entry
tick.  The resulting plan is *persistent*: after the initial fill it
reaches a steady state with no per-step fill/drain — the serving
engine's continuous-batching decode, where the feed carousel keeps
admitting the stream's own next steps (and, via an entry-zip overlay
source, freshly prefilled requests into retired slots) tick after tick.
With ``L >= handoff * D`` (e.g. 8 in-flight microbatches on 4 devices)
the steady state is bubble-free.
"""
from __future__ import annotations

import dataclasses

import numpy as np

SCHEDULES = ("gpipe", "one_f_one_b", "interleaved")

# Hand-off latency of the evaluator's issue-early/force-late ring: an
# output computed at tick t is ppermute'd *during* tick t+1 (overlapping
# that tick's compute) and consumable at tick t+2.
DEFAULT_HANDOFF = 2


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """Host-built tick tables for one (schedule, D, M, V) instance.

    Arrays of shape ``(num_ticks, num_stages)`` unless noted:

    Attributes:
      microbatch: microbatch worked by device d at tick t; -1 = idle.
      group: local cell-group (virtual stage) index in ``[0, V)``.
      read_slot: in-flight buffer slot the input comes from; -1 = inject
        a fresh item (only ever -1 where ``group == 0`` on device 0).
      recv_slot: slot in which the value *arriving* at tick t (sent by
        the ring predecessor during tick t) is stored; -1 = discard.
      collect: 1 where the produced output is a final result (only on
        device D-1, which owns the last virtual stage).
      inject / feed_reload / feed_advance: shape ``(num_ticks,)`` —
        item-feed carousel control for the primary source (see
        stream.py); ``feed_idx`` is the local item-shard index reloaded
        when ``feed_reload`` is set.  Aliases of row 0 of the
        generalized per-source tables below.
      inject_positions: one virtual-stage position per source; position
        0 is the chain entry.  Source *s* lives round-robin-sharded with
        offset ``inject_devices[s]`` and is delivered by its own
        reverse-ring carousel.
      inject_devices: ``inject_positions[s] % num_stages`` — the device
        that consumes source s.
      src_feed_reload / src_feed_idx / src_feed_advance / src_consume:
        shape ``(num_sources, num_ticks)`` — per-source carousel
        columns; ``src_consume[s, t]`` is 1 when source s's next item is
        merged into the flow at tick t (on device ``inject_devices[s]``).
      num_slots: in-flight buffer depth K (1 for gpipe, ~V interleaved).
    """

    name: str
    num_stages: int
    num_microbatches: int
    interleave: int
    handoff: int
    num_ticks: int
    microbatch: np.ndarray
    group: np.ndarray
    read_slot: np.ndarray
    recv_slot: np.ndarray
    collect: np.ndarray
    inject: np.ndarray
    feed_reload: np.ndarray
    feed_idx: np.ndarray
    feed_advance: np.ndarray
    num_slots: int
    inject_positions: tuple[int, ...] = (0,)
    inject_devices: tuple[int, ...] = (0,)
    src_feed_reload: np.ndarray | None = None
    src_feed_idx: np.ndarray | None = None
    src_feed_advance: np.ndarray | None = None
    src_consume: np.ndarray | None = None
    # Unfold/feedback plans: item b >= feedback_lag re-enters from item
    # b - feedback_lag's final output; only the first feedback_lag items
    # are primary-source fed.  None = ordinary feed-forward plan.
    feedback_lag: int | None = None

    @property
    def num_sources(self) -> int:
        return len(self.inject_positions)

    @property
    def bubble_fraction(self) -> float:
        """Idle fraction of the (ticks x devices) grid — measured, not modeled."""
        busy = int((self.microbatch >= 0).sum())
        return 1.0 - busy / (self.num_ticks * self.num_stages)

    @property
    def peak_inflight_items(self) -> int:
        """Modeled peak per-device activation stash (microbatches) under
        autodiff training — the schedule's memory term."""
        return peak_inflight_items(
            self.name,
            self.num_stages,
            self.num_microbatches,
            self.interleave,
            num_sources=self.num_sources,
        )


def peak_inflight_items(
    name: str,
    num_stages: int,
    num_microbatches: int,
    interleave: int = 1,
    num_sources: int = 1,
) -> int:
    """Peak per-device activation stash (microbatches) under autodiff
    training.  Single source of truth — chunking.schedule_peak_items and
    SchedulePlan.peak_inflight_items both delegate here.

    gpipe stashes every microbatch; 1F1B's steady state holds at most S;
    interleaved (Megatron 1F1B-style) holds one warm-up window per
    virtual chunk.  Every source past the first adds its feed storage —
    a local round-robin shard of ceil(M/S) items plus the one-item
    carousel register — measured in the same whole-item unit (the
    primary source's feed predates this model and is treated as part of
    the input batch, not the schedule's stash).
    """
    v = validate_schedule(name, interleave)
    feed = (num_sources - 1) * feed_items_per_source(num_stages, num_microbatches)
    if name == "one_f_one_b":
        return min(num_microbatches, num_stages) + feed
    if name == "interleaved":
        return min(v * num_microbatches, num_stages * v) + feed
    return num_microbatches + feed


def feed_items_per_source(num_stages: int, num_microbatches: int) -> int:
    """Per-device feed storage of ONE source, in items: its local
    round-robin shard (``ceil(M/D)``) plus the in-flight carousel
    register.  The single formula site — ``peak_inflight_items`` and
    ``chunking.feed_peak_items`` both delegate here."""
    return -(-num_microbatches // max(num_stages, 1)) + 1


def _allocate_slots(work, finish, num_stages: int, num_positions: int,
                    feedback_lag: int | None = None, num_items: int = 0):
    """Interval-graph coloring of in-flight hand-offs via smallest-free.

    (p, m) computed at tick tau on dev(p) is ppermute'd during tick
    tau+1 and lands on dev(p+1) = (dev+1) % D, where it occupies a slot
    until (p+1, m) reads it.  Under feedback the last position's output
    is a hand-off too: it rides the same ring hop (device D-1's
    successor is device 0) and occupies a device-0 slot until the entry
    unit ``(0, m + lag)`` reads it.
    Returns (recv_slot, read_slot, num_slots).
    """
    num_ticks = len(work)
    d_ = num_stages
    read_slot = np.full((num_ticks, d_), -1, np.int32)
    recv_slot = np.full((num_ticks, d_), -1, np.int32)
    free: list[list[int]] = [[] for _ in range(d_)]
    next_slot = [0] * d_
    release: dict[tuple[int, int], list[int]] = {}
    for tt in range(num_ticks):
        for dev in range(d_):
            for slot in release.pop((tt, dev), []):
                free[dev].append(slot)
        for dev in range(d_):
            unit = work[tt][dev]
            if unit is None:
                continue
            p, m = unit
            if p == num_positions - 1:
                if feedback_lag is None or m + feedback_lag >= num_items:
                    continue  # final output: collected, arrival discarded
                consume = finish[(0, m + feedback_lag)]
            else:
                consume = finish[(p + 1, m)]
            rdev = (dev + 1) % d_
            if free[rdev]:
                slot = min(free[rdev])
                free[rdev].remove(slot)
            else:
                slot = next_slot[rdev]
                next_slot[rdev] += 1
            recv_slot[tt + 1, rdev] = slot
            read_slot[consume, rdev] = slot
            release.setdefault((consume + 1, rdev), []).append(slot)
    return recv_slot, read_slot, max(1, max(next_slot))


def validate_schedule(name: str, interleave: int = 1) -> int:
    """Check (schedule, interleave) and return the effective V.

    Single validation shared by the plan builder, the evaluator, and the
    chunking model so a configuration the executor rejects can never
    yield a plausible modeled number.
    """
    if name not in SCHEDULES:
        raise ValueError(f"unknown schedule {name!r}; expected one of {SCHEDULES}")
    if name == "interleaved":
        if interleave < 1:
            raise ValueError(f"interleave must be >= 1, got {interleave}")
        return interleave
    if interleave != 1:
        raise ValueError(f"schedule {name!r} requires interleave=1, got {interleave}")
    return 1


def _validate(name: str, num_stages: int, num_microbatches: int, interleave: int):
    validate_schedule(name, interleave)
    if num_stages < 1 or num_microbatches < 1:
        raise ValueError("num_stages and num_microbatches must be >= 1")


def build_plan(
    name: str,
    num_stages: int,
    num_microbatches: int,
    interleave: int = 1,
    handoff: int = DEFAULT_HANDOFF,
    inject_positions: tuple[int, ...] = (0,),
    feedback_lag: int | None = None,
) -> SchedulePlan:
    """Greedy list-schedule of all (virtual stage, microbatch) units.

    Two unit priorities are tried and the best plan kept, comparing
    (makespan, in-flight buffer depth): microbatch-major ``(m, -p)``
    keeps the buffer depth O(V) and matches the closed-form makespan
    whenever D | M; chunk-major ``(p // D, m)`` can shave ticks on
    ragged M at the cost of deeper buffers.

    ``inject_positions`` generalizes the item-feed carousel to
    multi-source streams: one virtual-stage position per source (the
    first must be 0 — the chain entry).  Each source gets its own
    round-robin feed ring and reload/advance/consume columns; the tick
    tables themselves are position-oblivious, so injections never change
    the makespan — source s's item m is simply due on device
    ``p_s % D`` the tick unit ``(p_s, m)`` starts.

    ``feedback_lag=L`` builds a persistent (unfold) plan: entry unit
    ``(0, b)`` for ``b >= L`` becomes ready ``handoff`` ticks after the
    final position finished item ``b - L``, and only items ``b < L``
    are primary-source fed.  Feedback plans use the microbatch-major
    priority only — the chunk-major candidate's out-of-order finals
    would deadlock against the feedback dependency chain.
    """
    _validate(name, num_stages, num_microbatches, interleave)
    d_, m_, v_ = num_stages, num_microbatches, interleave
    num_positions = d_ * v_  # global virtual stages
    if feedback_lag is not None and not 1 <= feedback_lag <= m_:
        raise ValueError(
            f"feedback_lag must be in [1, num_microbatches={m_}], got "
            f"{feedback_lag}"
        )
    if not inject_positions or inject_positions[0] != 0:
        raise ValueError(
            f"inject_positions must start with the chain entry 0, got "
            f"{inject_positions}"
        )
    for p in inject_positions:
        if not 0 <= p < num_positions:
            raise ValueError(
                f"inject position {p} outside [0, {num_positions}) "
                f"(D={d_} x V={v_} virtual stages; post-pipeline merges "
                f"are applied by the evaluator, not the plan)"
            )

    # -- greedy simulation -------------------------------------------------
    def _greedy(priority):
        """Incremental list scheduling: units enter a per-device ready
        heap the tick their dependency clears (O(U log U) total — the
        naive rescan-all-pending version is O(M^2 D) and stalls tracing
        for thousand-microbatch streams)."""
        import heapq

        finish: dict[tuple[int, int], int] = {}  # (p, m) -> tick computed
        ready: list[list] = [[] for _ in range(d_)]  # per-device heaps
        becomes_ready: dict[int, list[tuple[int, int]]] = {}
        first_wave = m_ if feedback_lag is None else min(feedback_lag, m_)
        for m in range(first_wave):
            heapq.heappush(ready[0], (priority((0, m)), (0, m)))
        work: list[list[tuple[int, int] | None]] = []  # work[t][d] = (p, m)
        remaining = num_positions * m_
        t = 0
        while remaining:
            for unit in becomes_ready.pop(t, ()):
                heapq.heappush(ready[unit[0] % d_], (priority(unit), unit))
            row: list[tuple[int, int] | None] = [None] * d_
            for dev in range(d_):
                if ready[dev]:
                    row[dev] = heapq.heappop(ready[dev])[1]
            # successors become consumable `handoff` ticks after commit
            for unit in row:
                if unit is not None:
                    finish[unit] = t
                    remaining -= 1
                    p, m = unit
                    if p + 1 < num_positions:
                        becomes_ready.setdefault(t + handoff, []).append(
                            (p + 1, m)
                        )
                    elif feedback_lag is not None and m + feedback_lag < m_:
                        # The unfold edge: item m's final output is the
                        # entry input of item m + lag, one ring hop away.
                        becomes_ready.setdefault(t + handoff, []).append(
                            (0, m + feedback_lag)
                        )
            work.append(row)
            t += 1
            limit = (m_ + handoff) * (num_positions + 1) + 8
            if feedback_lag is not None:
                # Feedback serializes chains of m_/lag items end to end.
                limit += (handoff * num_positions + handoff) * (
                    m_ // max(feedback_lag, 1) + 1
                ) * max(1, m_)
            if t > limit:  # pragma: no cover
                raise RuntimeError(f"schedule {name} did not converge")
        return work, finish

    # Pick by (makespan, buffer depth): chunk-major can shave ticks on
    # ragged M but lets wraparound hand-offs pile up (K ~ O(M)), which
    # is exactly the memory blowup interleaved schedules exist to avoid.
    # Each candidate is slot-allocated exactly once; the winner's tables
    # are reused directly.
    priorities = [
        lambda u: (u[1], -u[0]),  # microbatch-major: K stays O(V)
    ]
    if feedback_lag is None:
        priorities.append(lambda u: (u[0] // d_, u[1]))  # chunk-major
    candidates = []
    for priority in priorities:
        work, finish = _greedy(priority)
        recv_slot, read_slot, num_slots = _allocate_slots(
            work, finish, d_, num_positions, feedback_lag, m_
        )
        candidates.append(
            (len(work), num_slots, work, finish, recv_slot, read_slot)
        )
    num_ticks, num_slots, work, finish, recv_slot, read_slot = min(
        candidates, key=lambda c: (c[0], c[1])
    )

    # -- tick tables -------------------------------------------------------
    microbatch = np.full((num_ticks, d_), -1, np.int32)
    group = np.zeros((num_ticks, d_), np.int32)
    collect = np.zeros((num_ticks, d_), np.int32)
    for tt, row in enumerate(work):
        for dev, unit in enumerate(row):
            if unit is None:
                continue
            p, m = unit
            microbatch[tt, dev] = m
            group[tt, dev] = p // d_
            if p == num_positions - 1:
                collect[tt, dev] = 1

    # -- item-feed carousels (one per source) ------------------------------
    # Source s's items are round-robin sharded with offset dev_s =
    # inject_positions[s] % D: item i lives on device (i + dev_s) % D, so
    # after j reverse-ring advances since a reload, device dev_s holds
    # exactly item base + j.  A per-source single-item register circulates
    # on the reverse ring (d -> d-1); every D consumptions every device
    # reloads from its local shard.  Stalls freeze the whole ring (the
    # advance flag is tick-uniform).  Consumption tick of source s's item
    # m is the start of unit (p_s, m) on device dev_s — the greedy
    # scheduler runs a position's units in microbatch order (asserted).
    num_src = len(inject_positions)
    inject_devices = tuple(p % d_ for p in inject_positions)
    src_feed_reload = np.zeros((num_src, num_ticks), np.int32)
    src_feed_idx = np.zeros((num_src, num_ticks), np.int32)
    src_consume = np.zeros((num_src, num_ticks), np.int32)
    for s, (p_s, dev_s) in enumerate(zip(inject_positions, inject_devices)):
        # Under feedback the primary source holds only the first `lag`
        # items; later entries re-enter from the in-flight buffers.
        # Every *other* source (entry-zip overlays, interior zips) still
        # delivers one item per stream position.
        feed_total = m_
        if s == 0 and feedback_lag is not None:
            feed_total = min(feedback_lag, m_)
        consumed = 0
        for tt in range(num_ticks):
            unit = work[tt][dev_s]
            if unit is not None and unit[0] == p_s:
                if s == 0 and unit[1] >= feed_total:
                    continue  # fed back, not carousel-fed
                assert unit[1] == consumed, (
                    f"source {s} consumed out of order at position {p_s}"
                )
                src_consume[s, tt] = 1
                if consumed % d_ == 0:
                    src_feed_reload[s, tt] = 1
                    src_feed_idx[s, tt] = consumed // d_
                consumed += 1
        assert consumed == feed_total
    src_feed_advance = src_consume.copy()

    # Primary-source injections are the units that read no slot;
    # fed-back entries are the units at position 0 that *do* read one.
    for tt in range(num_ticks):
        if src_consume[0, tt]:
            assert read_slot[tt, 0] == -1
        unit = work[tt][0]
        if (
            feedback_lag is not None
            and unit is not None
            and unit[0] == 0
            and unit[1] >= feedback_lag
        ):
            assert read_slot[tt, 0] >= 0, (
                f"feedback item {unit[1]} has no buffered input at tick {tt}"
            )

    return SchedulePlan(
        name=name,
        num_stages=d_,
        num_microbatches=m_,
        interleave=v_,
        handoff=handoff,
        num_ticks=num_ticks,
        microbatch=microbatch,
        group=group,
        read_slot=read_slot,
        recv_slot=recv_slot,
        collect=collect,
        inject=src_consume[0].copy(),
        feed_reload=src_feed_reload[0],
        feed_idx=src_feed_idx[0],
        feed_advance=src_feed_advance[0],
        num_slots=num_slots,
        inject_positions=tuple(inject_positions),
        inject_devices=inject_devices,
        src_feed_reload=src_feed_reload,
        src_feed_idx=src_feed_idx,
        src_feed_advance=src_feed_advance,
        src_consume=src_consume,
        feedback_lag=feedback_lag,
    )
