"""Pipeline schedules as data: per-tick (stage, microbatch, group) plans.

The Future evaluator (:mod:`repro.core.stream`) is a plan *executor*: it
runs a ``lax.scan`` whose per-tick behaviour — which microbatch each
device works on, which of its local cell groups it applies, where its
input comes from (fresh injection vs. a received in-flight buffer slot),
and whether its output is a final result — is read from host-built int32
tables.  A :class:`SchedulePlan` is those tables plus the buffer-slot and
item-feed bookkeeping the executor needs.  Building plans on the host
keeps the device program schedule-oblivious: new schedules are new table
builders, not new evaluators.

Three schedules ship:

``gpipe``
    Fill/drain.  Stage ``s`` runs microbatch ``m`` at tick
    ``h*s + m`` where ``h`` is the hand-off latency (2 for the
    issue-early/force-late ring used by the evaluator).  Peak in-flight
    activation stash under autodiff training: all ``M`` microbatches.

``one_f_one_b``
    1F1B.  The *executed forward* plan is tick-identical to GPipe (the
    backward is derived by ``jax.grad``, which reverses the forward
    scan; true interleaved F/B execution would need a hand-written VJP
    pipeline — an open item).  What differs is the modeled training
    schedule: steady-state activation stash is ``min(S, M)``
    microbatches instead of ``M``, which is what
    :func:`repro.core.chunking.optimal_schedule` uses to admit larger
    ``M`` under a memory budget.

``interleaved``
    Each device owns ``V`` non-contiguous cell groups (virtual stages;
    global virtual stage ``p`` lives on device ``p % D``).  Per-tick
    work shrinks by ``V`` while the fill/drain tick count stays
    ``h*(D-1)``, cutting the bubble from ``h(D-1)/(M + h(D-1))`` to
    ``h(D-1)/(V*M + h(D-1))`` — Megatron-style interleaving expressed
    as a stream-of-futures plan.  The hand-off stays a single ring
    ``ppermute`` because consecutive virtual stages always sit on
    ring-adjacent devices (``p+1`` lives on ``(d+1) % D``).

Plans are built by a greedy list scheduler (priority: lowest microbatch,
then deepest virtual stage) under two constraints: a device runs one
unit per tick, and unit ``(p, m)`` may start ``handoff`` ticks after
``(p-1, m)`` finished.  For ``M >= D`` this achieves the closed-form
tick counts above; the plan's own ``num_ticks``/``bubble_fraction`` are
always the ground truth (and are tested against the analytic model).

**Feedback (persistent) plans** — ``feedback_lag=L`` adds the unfold
combinator's dependency: item ``b``'s entry unit ``(0, b)`` (for
``b >= L``) becomes ready only ``handoff`` ticks after the *last*
virtual stage finished item ``b - L``.  Only the first ``L`` items are
fed from the primary source's carousel; every later item re-enters from
its own output, carried by the same one-hop ring (the last virtual
stage always lives on device D-1, whose ring successor is device 0) and
parked in the same interval-colored in-flight buffers until its entry
tick.  The resulting plan is *persistent*: after the initial fill it
reaches a steady state with no per-step fill/drain — the serving
engine's continuous-batching decode, where the feed carousel keeps
admitting the stream's own next steps (and, via an entry-zip overlay
source, freshly prefilled requests into retired slots) tick after tick.
With ``L >= handoff * D`` (e.g. 8 in-flight microbatches on 4 devices)
the steady state is bubble-free.

**Combined (training) plans** — :func:`build_combined_plan` schedules
the backward pass as first-class units in the *same* tick table instead
of leaving it to whatever ``jax.grad`` derives from the forward plan.
Unit kinds are ``F`` (forward), ``B`` (backward) and — with
``split_backward=True`` — ``W`` (weight grad, the zero-bubble 3-way
split; see ``UNIT_F``/``UNIT_B``/``UNIT_W``).  Under ``one_f_one_b``
the builder interleaves F and B in true 1F1B order by capping each
device's live activation stash, so the plan's own stash/release columns
bound peak concurrently-stashed activations at ``V * min(S, M)`` items
(``min(S, M)`` for the plain V=1 schedule) versus ``M`` for gpipe's
fill-then-drain.  The executed realization is
``FutureEvaluator(..., backward="planned")`` — see
:class:`CombinedPlan` for how the plan's combined schedule relates to
the custom-VJP two-phase execution.

The tick-plan column contract
=============================

This section is the single normative description of the tables a
:class:`SchedulePlan` hands to the executor
(:class:`repro.core.stream.FutureEvaluator`); the executor's and
chunking model's docstrings refer here instead of restating it.
All tables have shape ``(num_ticks, num_stages)`` and are consumed as
``lax.scan`` xs rows, except the feed columns, which are tick-indexed
(``(num_sources, num_ticks)``).

Per-device unit columns
    ``microbatch[t, d]`` is the item device ``d`` advances at tick
    ``t`` (-1 = idle; idle ticks still run the ring send, and their
    outputs are never stored or collected).  ``group[t, d]`` selects
    which of the device's ``V`` local cell groups applies (virtual
    stage ``group * D + d``).  ``collect[t, d]`` marks final-position
    units: the produced item is a result (written to the last device's
    output block) and, under feedback, also the value that re-enters
    the chain.

Hand-off columns (the in-flight ring buffers)
    A value computed at tick ``t`` on device ``d`` is ppermute'd during
    tick ``t+1`` (overlapping that tick's compute — the Future) and is
    consumable on device ``(d+1) % D`` at ``t+2`` (= ``handoff``).
    ``recv_slot[t, d]`` says where the value *arriving* at tick ``t``
    is parked (-1 = discard); ``read_slot[t, d]`` says which parked
    slot this tick's unit consumes (-1 = the input is a fresh
    injection from the feed registers instead).  Slots are per-device
    interval-graph colors (:func:`_allocate_slots`), so ``num_slots``
    is exactly the peak number of concurrently in-flight hand-offs.

Feed columns (one carousel per source)
    Source ``s`` is round-robin sharded over the stage axis with
    rotation offset ``inject_devices[s]`` and circulates one register
    per device on the reverse ring.  ``src_feed_reload[s, t]`` = load
    the local shard row ``src_feed_idx[s, t]`` into the register;
    ``src_feed_advance[s, t]`` = rotate the ring one hop after this
    tick; ``src_consume[s, t]`` = the register on device
    ``inject_devices[s]`` is merged into the flow this tick (for the
    primary source that *is* the unit input; for zip sources it is
    combined in).  Reloads happen every D-th consumption.

Feedback arcs
    Under ``feedback_lag=L`` the final position's output is itself a
    hand-off: it rides the same one-hop ring (device D-1 → 0) into a
    device-0 slot recorded in ``recv_slot``, and the entry unit
    ``(0, m)`` for ``m >= L`` has ``read_slot >= 0`` — a fed-back
    entry — instead of a carousel consume.

Emit placement (feedback plans only)
    ``emit[t, d]`` marks the units whose produced item must pass
    through the feedback ``emit`` (final-norm → logits → sample →
    re-embed for a decode chain) before being collected and handed
    back on the ring.  It equals ``collect`` on feedback plans and is
    all-zero otherwise, but is a separate column on purpose: emit
    placement is part of the plan contract, and the builder guarantees
    ``emit`` is nonzero **only on the device owning the final virtual
    stage** (device D-1 — virtual stage ``D*V - 1`` lives there).
    That is the plan-level half of the last-stage-only emit split: the
    executor keys the emit region off this column, so the LM head is
    structurally confined to one device's conditional region and the
    other D-1 devices' tick bodies never execute it (HLO-asserted in
    the serving tests).

Stash/release columns (combined plans only)
    :class:`CombinedPlan` adds ``stash_slot[t, d]`` (the per-device
    stash color an F unit's input activation is saved into; -1
    elsewhere) and ``release_slot[t, d]`` (the color freed once the
    matching B — or W, when split — unit has consumed it).  Colors are
    the same smallest-free interval allocation as the hand-off slots,
    so ``num_stash_slots`` equals the peak number of concurrently
    stashed activations; :meth:`CombinedPlan.peak_stash_items` recomputes
    that peak directly from the columns.
"""
from __future__ import annotations

import dataclasses

import numpy as np

SCHEDULES = ("gpipe", "one_f_one_b", "interleaved")

# How the training backward pass is executed against a forward plan:
# "autodiff" lets jax.grad transpose the forward tick scan (every
# schedule then stashes all V*M unit inputs per device); "planned" runs
# the combined plan's B units through the custom-VJP executor
# (FutureEvaluator(backward="planned")), whose schedule-level stash is
# the combined plan's own peak.  Canonical home of the mode names —
# configs.base re-exports them.
BACKWARD_MODES = ("autodiff", "planned")

# Unit kinds of a combined plan's tick table.
UNIT_F, UNIT_B, UNIT_W = 0, 1, 2


def validate_backward(mode: str) -> str:
    if mode not in BACKWARD_MODES:
        raise ValueError(
            f"unknown backward mode {mode!r}; expected one of {BACKWARD_MODES}"
        )
    return mode

# Hand-off latency of the evaluator's issue-early/force-late ring: an
# output computed at tick t is ppermute'd *during* tick t+1 (overlapping
# that tick's compute) and consumable at tick t+2.
DEFAULT_HANDOFF = 2


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """Host-built tick tables for one (schedule, D, M, V) instance.

    Column semantics are defined once, in "The tick-plan column
    contract" section of this module's docstring — per-device unit
    columns (``microbatch``/``group``/``collect``), hand-off slots
    (``read_slot``/``recv_slot``/``num_slots``), per-source feed
    carousels (``src_feed_reload``/``src_feed_idx``/
    ``src_feed_advance``/``src_consume``, with ``inject``/``feed_*``
    aliasing source 0), and feedback arcs.  ``inject_positions`` /
    ``inject_devices`` give each source's virtual-stage position and
    consuming device.
    """

    name: str
    num_stages: int
    num_microbatches: int
    interleave: int
    handoff: int
    num_ticks: int
    microbatch: np.ndarray
    group: np.ndarray
    read_slot: np.ndarray
    recv_slot: np.ndarray
    collect: np.ndarray
    inject: np.ndarray
    feed_reload: np.ndarray
    feed_idx: np.ndarray
    feed_advance: np.ndarray
    num_slots: int
    inject_positions: tuple[int, ...] = (0,)
    inject_devices: tuple[int, ...] = (0,)
    src_feed_reload: np.ndarray | None = None
    src_feed_idx: np.ndarray | None = None
    src_feed_advance: np.ndarray | None = None
    src_consume: np.ndarray | None = None
    # Unfold/feedback plans: item b >= feedback_lag re-enters from item
    # b - feedback_lag's final output; only the first feedback_lag items
    # are primary-source fed.  None = ordinary feed-forward plan.
    feedback_lag: int | None = None
    # Emit placement (see the column contract): == collect on feedback
    # plans, all-zero otherwise; nonzero only on the final-stage device.
    emit: np.ndarray | None = None

    @property
    def num_sources(self) -> int:
        return len(self.inject_positions)

    @property
    def bubble_fraction(self) -> float:
        """Idle fraction of the (ticks x devices) grid — measured, not modeled."""
        busy = int((self.microbatch >= 0).sum())
        return 1.0 - busy / (self.num_ticks * self.num_stages)

    @property
    def peak_inflight_items(self) -> int:
        """Modeled peak per-device activation stash (microbatches) under
        the schedule's own (planned-backward) combined plan — the
        schedule's memory term; see :func:`peak_inflight_items` for the
        autodiff-mode variant."""
        return peak_inflight_items(
            self.name,
            self.num_stages,
            self.num_microbatches,
            self.interleave,
            num_sources=self.num_sources,
        )


def peak_inflight_items(
    name: str,
    num_stages: int,
    num_microbatches: int,
    interleave: int = 1,
    num_sources: int = 1,
    backward: str = "planned",
) -> int:
    """Peak per-device activation stash (microbatches) under training.
    Single source of truth — chunking.schedule_peak_items and
    SchedulePlan.peak_inflight_items both delegate here.

    ``backward="planned"`` scores the schedule's *own* combined plan
    (:func:`build_combined_plan`): gpipe fill-then-drain stashes every
    unit input (``V*M``); 1F1B's interleaved F/B steady state holds at
    most ``min(S, M)``; interleaved holds ``V * min(S, M)``.  These
    closed forms are exact against the combined plans' stash/release
    columns (tested over the grid).  ``backward="autodiff"`` is the
    degraded truth of letting ``jax.grad`` transpose the forward scan:
    the fwd/bwd phase boundary keeps **all** ``V*M`` unit inputs live
    regardless of schedule name — before the planned backward existed,
    1F1B's ``min(S, M)`` was a modeling assumption the execution never
    realized.

    Every source past the first adds its feed storage — a local
    round-robin shard of ceil(M/S) items plus the one-item carousel
    register — measured in the same whole-item unit (the primary
    source's feed predates this model and is treated as part of the
    input batch, not the schedule's stash).
    """
    v = validate_schedule(name, interleave)
    validate_backward(backward)
    feed = (num_sources - 1) * feed_items_per_source(num_stages, num_microbatches)
    if backward == "autodiff":
        return v * num_microbatches + feed
    if name == "one_f_one_b":
        return min(num_microbatches, num_stages) + feed
    if name == "interleaved":
        return min(v * num_microbatches, num_stages * v) + feed
    return num_microbatches + feed


def feed_items_per_source(num_stages: int, num_microbatches: int) -> int:
    """Per-device feed storage of ONE source, in items: its local
    round-robin shard (``ceil(M/D)``) plus the in-flight carousel
    register.  The single formula site — ``peak_inflight_items`` and
    ``chunking.feed_peak_items`` both delegate here."""
    return -(-num_microbatches // max(num_stages, 1)) + 1


def _allocate_slots(work, finish, num_stages: int, num_positions: int,
                    feedback_lag: int | None = None, num_items: int = 0):
    """Interval-graph coloring of in-flight hand-offs via smallest-free.

    (p, m) computed at tick tau on dev(p) is ppermute'd during tick
    tau+1 and lands on dev(p+1) = (dev+1) % D, where it occupies a slot
    until (p+1, m) reads it.  Under feedback the last position's output
    is a hand-off too: it rides the same ring hop (device D-1's
    successor is device 0) and occupies a device-0 slot until the entry
    unit ``(0, m + lag)`` reads it.
    Returns (recv_slot, read_slot, num_slots).
    """
    num_ticks = len(work)
    d_ = num_stages
    read_slot = np.full((num_ticks, d_), -1, np.int32)
    recv_slot = np.full((num_ticks, d_), -1, np.int32)
    free: list[list[int]] = [[] for _ in range(d_)]
    next_slot = [0] * d_
    release: dict[tuple[int, int], list[int]] = {}
    for tt in range(num_ticks):
        for dev in range(d_):
            for slot in release.pop((tt, dev), []):
                free[dev].append(slot)
        for dev in range(d_):
            unit = work[tt][dev]
            if unit is None:
                continue
            p, m = unit
            if p == num_positions - 1:
                if feedback_lag is None or m + feedback_lag >= num_items:
                    continue  # final output: collected, arrival discarded
                consume = finish[(0, m + feedback_lag)]
            else:
                consume = finish[(p + 1, m)]
            rdev = (dev + 1) % d_
            if free[rdev]:
                slot = min(free[rdev])
                free[rdev].remove(slot)
            else:
                slot = next_slot[rdev]
                next_slot[rdev] += 1
            recv_slot[tt + 1, rdev] = slot
            read_slot[consume, rdev] = slot
            release.setdefault((consume + 1, rdev), []).append(slot)
    return recv_slot, read_slot, max(1, max(next_slot))


def validate_schedule(name: str, interleave: int = 1) -> int:
    """Check (schedule, interleave) and return the effective V.

    Single validation shared by the plan builder, the evaluator, and the
    chunking model so a configuration the executor rejects can never
    yield a plausible modeled number.
    """
    if name not in SCHEDULES:
        raise ValueError(f"unknown schedule {name!r}; expected one of {SCHEDULES}")
    if name == "interleaved":
        if interleave < 1:
            raise ValueError(f"interleave must be >= 1, got {interleave}")
        return interleave
    if interleave != 1:
        raise ValueError(f"schedule {name!r} requires interleave=1, got {interleave}")
    return 1


def _validate(name: str, num_stages: int, num_microbatches: int, interleave: int):
    validate_schedule(name, interleave)
    if num_stages < 1 or num_microbatches < 1:
        raise ValueError("num_stages and num_microbatches must be >= 1")


def build_plan(
    name: str,
    num_stages: int,
    num_microbatches: int,
    interleave: int = 1,
    handoff: int = DEFAULT_HANDOFF,
    inject_positions: tuple[int, ...] = (0,),
    feedback_lag: int | None = None,
) -> SchedulePlan:
    """Greedy list-schedule of all (virtual stage, microbatch) units.

    Two unit priorities are tried and the best plan kept, comparing
    (makespan, in-flight buffer depth): microbatch-major ``(m, -p)``
    keeps the buffer depth O(V) and matches the closed-form makespan
    whenever D | M; chunk-major ``(p // D, m)`` can shave ticks on
    ragged M at the cost of deeper buffers.

    ``inject_positions`` generalizes the item-feed carousel to
    multi-source streams: one virtual-stage position per source (the
    first must be 0 — the chain entry).  Each source gets its own
    round-robin feed ring and reload/advance/consume columns; the tick
    tables themselves are position-oblivious, so injections never change
    the makespan — source s's item m is simply due on device
    ``p_s % D`` the tick unit ``(p_s, m)`` starts.

    ``feedback_lag=L`` builds a persistent (unfold) plan: entry unit
    ``(0, b)`` for ``b >= L`` becomes ready ``handoff`` ticks after the
    final position finished item ``b - L``, and only items ``b < L``
    are primary-source fed.  Feedback plans use the microbatch-major
    priority only — the chunk-major candidate's out-of-order finals
    would deadlock against the feedback dependency chain.
    """
    _validate(name, num_stages, num_microbatches, interleave)
    d_, m_, v_ = num_stages, num_microbatches, interleave
    num_positions = d_ * v_  # global virtual stages
    if feedback_lag is not None and not 1 <= feedback_lag <= m_:
        raise ValueError(
            f"feedback_lag must be in [1, num_microbatches={m_}], got "
            f"{feedback_lag}"
        )
    if not inject_positions or inject_positions[0] != 0:
        raise ValueError(
            f"inject_positions must start with the chain entry 0, got "
            f"{inject_positions}"
        )
    for p in inject_positions:
        if not 0 <= p < num_positions:
            raise ValueError(
                f"inject position {p} outside [0, {num_positions}) "
                f"(D={d_} x V={v_} virtual stages; post-pipeline merges "
                f"are applied by the evaluator, not the plan)"
            )

    # -- greedy simulation -------------------------------------------------
    def _greedy(priority):
        """Incremental list scheduling: units enter a per-device ready
        heap the tick their dependency clears (O(U log U) total — the
        naive rescan-all-pending version is O(M^2 D) and stalls tracing
        for thousand-microbatch streams)."""
        import heapq

        finish: dict[tuple[int, int], int] = {}  # (p, m) -> tick computed
        ready: list[list] = [[] for _ in range(d_)]  # per-device heaps
        becomes_ready: dict[int, list[tuple[int, int]]] = {}
        first_wave = m_ if feedback_lag is None else min(feedback_lag, m_)
        for m in range(first_wave):
            heapq.heappush(ready[0], (priority((0, m)), (0, m)))
        work: list[list[tuple[int, int] | None]] = []  # work[t][d] = (p, m)
        remaining = num_positions * m_
        t = 0
        while remaining:
            for unit in becomes_ready.pop(t, ()):
                heapq.heappush(ready[unit[0] % d_], (priority(unit), unit))
            row: list[tuple[int, int] | None] = [None] * d_
            for dev in range(d_):
                if ready[dev]:
                    row[dev] = heapq.heappop(ready[dev])[1]
            # successors become consumable `handoff` ticks after commit
            for unit in row:
                if unit is not None:
                    finish[unit] = t
                    remaining -= 1
                    p, m = unit
                    if p + 1 < num_positions:
                        becomes_ready.setdefault(t + handoff, []).append(
                            (p + 1, m)
                        )
                    elif feedback_lag is not None and m + feedback_lag < m_:
                        # The unfold edge: item m's final output is the
                        # entry input of item m + lag, one ring hop away.
                        becomes_ready.setdefault(t + handoff, []).append(
                            (0, m + feedback_lag)
                        )
            work.append(row)
            t += 1
            limit = (m_ + handoff) * (num_positions + 1) + 8
            if feedback_lag is not None:
                # Feedback serializes chains of m_/lag items end to end.
                limit += (handoff * num_positions + handoff) * (
                    m_ // max(feedback_lag, 1) + 1
                ) * max(1, m_)
            if t > limit:  # pragma: no cover
                raise RuntimeError(f"schedule {name} did not converge")
        return work, finish

    # Pick by (makespan, buffer depth): chunk-major can shave ticks on
    # ragged M but lets wraparound hand-offs pile up (K ~ O(M)), which
    # is exactly the memory blowup interleaved schedules exist to avoid.
    # Each candidate is slot-allocated exactly once; the winner's tables
    # are reused directly.
    priorities = [
        lambda u: (u[1], -u[0]),  # microbatch-major: K stays O(V)
    ]
    if feedback_lag is None:
        priorities.append(lambda u: (u[0] // d_, u[1]))  # chunk-major
    candidates = []
    for priority in priorities:
        work, finish = _greedy(priority)
        recv_slot, read_slot, num_slots = _allocate_slots(
            work, finish, d_, num_positions, feedback_lag, m_
        )
        candidates.append(
            (len(work), num_slots, work, finish, recv_slot, read_slot)
        )
    num_ticks, num_slots, work, finish, recv_slot, read_slot = min(
        candidates, key=lambda c: (c[0], c[1])
    )

    # -- tick tables -------------------------------------------------------
    microbatch = np.full((num_ticks, d_), -1, np.int32)
    group = np.zeros((num_ticks, d_), np.int32)
    collect = np.zeros((num_ticks, d_), np.int32)
    for tt, row in enumerate(work):
        for dev, unit in enumerate(row):
            if unit is None:
                continue
            p, m = unit
            microbatch[tt, dev] = m
            group[tt, dev] = p // d_
            if p == num_positions - 1:
                collect[tt, dev] = 1
    # Emit placement: under feedback, exactly the final-position units
    # (what collect marks); the final virtual stage D*V-1 lives on device
    # D-1, so emit is last-stage-only by construction — asserted here so
    # the executor may key its only head region off this column.
    emit = collect.copy() if feedback_lag is not None else np.zeros_like(collect)
    assert emit[:, : d_ - 1].sum() == 0, "emit must be last-stage-only"

    # -- item-feed carousels (one per source) ------------------------------
    # Source s's items are round-robin sharded with offset dev_s =
    # inject_positions[s] % D: item i lives on device (i + dev_s) % D, so
    # after j reverse-ring advances since a reload, device dev_s holds
    # exactly item base + j.  A per-source single-item register circulates
    # on the reverse ring (d -> d-1); every D consumptions every device
    # reloads from its local shard.  Stalls freeze the whole ring (the
    # advance flag is tick-uniform).  Consumption tick of source s's item
    # m is the start of unit (p_s, m) on device dev_s — the greedy
    # scheduler runs a position's units in microbatch order (asserted).
    num_src = len(inject_positions)
    inject_devices = tuple(p % d_ for p in inject_positions)
    src_feed_reload = np.zeros((num_src, num_ticks), np.int32)
    src_feed_idx = np.zeros((num_src, num_ticks), np.int32)
    src_consume = np.zeros((num_src, num_ticks), np.int32)
    for s, (p_s, dev_s) in enumerate(zip(inject_positions, inject_devices)):
        # Under feedback the primary source holds only the first `lag`
        # items; later entries re-enter from the in-flight buffers.
        # Every *other* source (entry-zip overlays, interior zips) still
        # delivers one item per stream position.
        feed_total = m_
        if s == 0 and feedback_lag is not None:
            feed_total = min(feedback_lag, m_)
        consumed = 0
        for tt in range(num_ticks):
            unit = work[tt][dev_s]
            if unit is not None and unit[0] == p_s:
                if s == 0 and unit[1] >= feed_total:
                    continue  # fed back, not carousel-fed
                assert unit[1] == consumed, (
                    f"source {s} consumed out of order at position {p_s}"
                )
                src_consume[s, tt] = 1
                if consumed % d_ == 0:
                    src_feed_reload[s, tt] = 1
                    src_feed_idx[s, tt] = consumed // d_
                consumed += 1
        assert consumed == feed_total
    src_feed_advance = src_consume.copy()

    # Primary-source injections are the units that read no slot;
    # fed-back entries are the units at position 0 that *do* read one.
    for tt in range(num_ticks):
        if src_consume[0, tt]:
            assert read_slot[tt, 0] == -1
        unit = work[tt][0]
        if (
            feedback_lag is not None
            and unit is not None
            and unit[0] == 0
            and unit[1] >= feedback_lag
        ):
            assert read_slot[tt, 0] >= 0, (
                f"feedback item {unit[1]} has no buffered input at tick {tt}"
            )

    return SchedulePlan(
        name=name,
        num_stages=d_,
        num_microbatches=m_,
        interleave=v_,
        handoff=handoff,
        num_ticks=num_ticks,
        microbatch=microbatch,
        group=group,
        read_slot=read_slot,
        recv_slot=recv_slot,
        collect=collect,
        inject=src_consume[0].copy(),
        feed_reload=src_feed_reload[0],
        feed_idx=src_feed_idx[0],
        feed_advance=src_feed_advance[0],
        num_slots=num_slots,
        inject_positions=tuple(inject_positions),
        inject_devices=inject_devices,
        src_feed_reload=src_feed_reload,
        src_feed_idx=src_feed_idx,
        src_feed_advance=src_feed_advance,
        src_consume=src_consume,
        feedback_lag=feedback_lag,
        emit=emit,
    )


# ---------------------------------------------------------------------------
# Combined forward+backward plans (true 1F1B; ZB 3-way groundwork)
# ---------------------------------------------------------------------------


def build_backward_plan(
    name: str,
    num_stages: int,
    num_microbatches: int,
    interleave: int = 1,
    handoff: int = DEFAULT_HANDOFF,
) -> SchedulePlan:
    """The B-phase execution tables: a forward plan, mirrored.

    The backward pipeline is the forward one reflected through the ring:
    B unit ``(p, m)`` runs on the same device as F unit ``(p, m)`` and
    depends on ``(p+1, m)`` one *reverse*-ring hop away, so relabelling
    positions ``r = P-1-p`` and devices ``d -> D-1-d`` turns the B-unit
    dependency graph into exactly the forward one.  We therefore reuse
    :func:`build_plan` and flip its device columns, reinterpreting the
    tables for the executor's backward scan:

    * ``microbatch[t, d]`` / ``group[t, d]`` — the B unit ``(group*D+d,
      m)`` device d transposes at tick t (cotangent in, cotangent +
      weight-grad contribution out);
    * ``read_slot`` — the in-flight *cotangent* slot consumed (-1 at
      the last position, whose seed ``d_out[m]`` arrives by carousel);
    * ``recv_slot`` — where the cotangent arriving on the ring from
      device ``(d+1) % D`` is parked (the mirror of the forward hop:
      sends travel the reverse ring);
    * ``collect`` — marks entry units ``(0, m)`` on device 0, whose
      produced cotangent is the source-item gradient ``d_items[m]``;
    * feed columns — the ``d_out`` seed carousel.  Seeds are sharded
      with the *flipped* round-robin layout (device d holds items
      ``j*D + (D-1-d)``) and circulate on the forward ring so seed m
      reaches device D-1 at its m-th consumption.

    The unit ordering equals the B-unit subsequence of
    :func:`build_combined_plan` (each position's units run in
    microbatch order in both); the combined table is the schedule
    artifact, this is what the custom-VJP bwd phase executes.
    """
    fwd = build_plan(name, num_stages, num_microbatches, interleave, handoff)
    flip = lambda a: np.ascontiguousarray(a[:, ::-1])
    return dataclasses.replace(
        fwd,
        microbatch=flip(fwd.microbatch),
        group=flip((fwd.interleave - 1) - fwd.group),
        read_slot=flip(fwd.read_slot),
        recv_slot=flip(fwd.recv_slot),
        collect=flip(fwd.collect),
        emit=flip(fwd.emit),
        inject_devices=(num_stages - 1,),
    )


@dataclasses.dataclass(frozen=True)
class CombinedPlan:
    """One tick table scheduling forward *and* backward units.

    This is the schedule artifact of training under a hand-written
    (planned) backward: every device runs at most one unit per tick, a
    unit is ``(kind, position, microbatch)`` with kind ``UNIT_F`` /
    ``UNIT_B`` / ``UNIT_W``, and the stash/release columns (see the
    column contract in the module docstring) prove the peak number of
    concurrently live activation stashes from the table itself —
    ``min(S, M)`` per device for ``one_f_one_b`` (the 1F1B memory
    bound, now a plan property instead of a modeling assumption) vs
    ``M`` for gpipe's fill-then-drain.

    Execution: :class:`repro.core.stream.FutureEvaluator` with
    ``backward="planned"`` realizes the combined plan under XLA's
    two-phase autodiff protocol — ``jax.custom_vjp`` runs all F units
    (the ``forward`` plan, identical tables to :func:`build_plan`)
    before any B unit (the ``backward`` plan, same unit order as this
    table's B subsequence).  At that phase boundary all ``V*M`` stashes
    are live regardless of schedule, so the executed stash buffers are
    indexed ``group * M + m``; the interleaved stash/release coloring
    here is what a fused runtime (loss computed in-pipeline, B units
    issued as seeds arrive — the ZB executor follow-on) realizes, and
    is what :func:`repro.core.chunking.schedule_peak_items` scores
    under ``backward="planned"``.

    Attributes (all ``(num_ticks, num_stages)`` unless noted):
      kind: unit kind at (tick, device); -1 = idle.
      microbatch: the unit's item; -1 = idle.
      position: the unit's global virtual stage in ``[0, D*V)``.
      stash_slot: per-device stash color written by an F unit; -1 else.
      release_slot: stash color freed after this unit (the B unit, or
        the W unit when ``split_backward``); -1 else.
      num_stash_slots: interval-coloring count == peak live stashes.
      forward / backward: the two phase-execution table sets.
    """

    name: str
    num_stages: int
    num_microbatches: int
    interleave: int
    handoff: int
    split_backward: bool
    num_ticks: int
    kind: np.ndarray
    microbatch: np.ndarray
    position: np.ndarray
    stash_slot: np.ndarray
    release_slot: np.ndarray
    num_stash_slots: int
    forward: SchedulePlan
    backward: SchedulePlan

    @property
    def peak_stash_items(self) -> int:
        """Peak concurrently-stashed activations (in items), recomputed
        from the stash/release columns: a stash is live from its F tick
        through its releasing unit's tick inclusive."""
        peak = 0
        for dev in range(self.num_stages):
            live = 0
            for t in range(self.num_ticks):
                if self.stash_slot[t, dev] >= 0:
                    live += 1
                peak = max(peak, live)
                if self.release_slot[t, dev] >= 0:
                    live -= 1
        return peak

    @property
    def bubble_fraction(self) -> float:
        """Idle fraction of the combined (ticks x devices) grid."""
        busy = int((self.kind >= 0).sum())
        return 1.0 - busy / (self.num_ticks * self.num_stages)


def build_combined_plan(
    name: str,
    num_stages: int,
    num_microbatches: int,
    interleave: int = 1,
    handoff: int = DEFAULT_HANDOFF,
    split_backward: bool = False,
) -> CombinedPlan:
    """Greedy list-schedule of F, B (and optionally W) units jointly.

    Dependencies: ``F(p, m)`` is consumable ``handoff`` ticks after
    ``F(p-1, m)``; ``B(P-1, m)`` one tick after ``F(P-1, m)`` (the
    local loss turnaround — no ring hop); ``B(p, m)`` ``handoff`` ticks
    after ``B(p+1, m)``; ``W(p, m)`` one tick after ``B(p, m)`` (same
    device, any later tick — the ZB-H1 bubble filler).

    Schedule semantics:

    * ``gpipe`` — phase-gated: no B unit starts until every F unit has
      run (fill then drain), so every device's stash peaks at its full
      ``V*M`` unit inputs.
    * ``one_f_one_b`` / ``interleaved`` — B units take priority over F
      the moment their cotangent is available, and a device may not
      start a new F unit while ``V * min(S, M)`` stashes are live (the
      1F1B in-flight cap).  The steady state is the classic 1F1B
      alternation and the stash bound is realized *by construction* —
      asserted from the plan columns in the tier-1 tests, not modeled.

    ``split_backward=True`` emits the 3-way unit split: B units carry
    only the activation grad, W units the weight grad, and the stash is
    released at W (both consume it).  The executor does not run split
    plans yet (ZB-H1 is a follow-on plan *consumer*, not a new
    builder); the tables are the groundwork.
    """
    import heapq

    _validate(name, num_stages, num_microbatches, interleave)
    d_, m_, v_ = num_stages, num_microbatches, interleave
    num_positions = d_ * v_
    p_last = num_positions - 1
    kinds = (UNIT_F, UNIT_B, UNIT_W) if split_backward else (UNIT_F, UNIT_B)
    # 1F1B live-stash cap: min(S, M) items per (device, local group) —
    # per-group rather than per-device so a shallow group saturating its
    # stash can never starve the deeper groups its own drain depends on
    # (a flat per-device cap deadlocks interleaved plans).  Per-device
    # total: V * min(S, M).
    cap = min(d_, m_)
    gpipe_gated = name == "gpipe"
    release_kind = UNIT_W if split_backward else UNIT_B

    def dev_of(p):
        return p % d_

    def priority(unit):
        kind, p, m = unit
        # B drains stashes first; F fills; W mops up bubbles.  Within a
        # kind, lowest microbatch first, F deepest-position first (the
        # forward builder's microbatch-major key), B shallowest first.
        rank = {UNIT_B: 0, UNIT_F: 1, UNIT_W: 2}[kind]
        return (rank, m, -p if kind == UNIT_F else p)

    finish: dict[tuple[int, int, int], int] = {}
    ready: list[list] = [[] for _ in range(d_)]
    becomes_ready: dict[int, list[tuple[int, int, int]]] = {}
    deferred_b: list[tuple[int, int, int]] = []  # gpipe phase gate
    for m in range(m_):
        heapq.heappush(ready[0], (priority((UNIT_F, 0, m)), (UNIT_F, 0, m)))
    live = [[0] * v_ for _ in range(d_)]
    remaining = num_positions * m_ * len(kinds)
    remaining_f = num_positions * m_
    work: list[list[tuple[int, int, int] | None]] = []
    t = 0
    limit = (len(kinds) * (m_ + handoff) * (num_positions + 1) + 8) * (
        2 + 2 * handoff
    )
    while remaining:
        for unit in becomes_ready.pop(t, ()):
            if gpipe_gated and unit[0] != UNIT_F and remaining_f:
                deferred_b.append(unit)
            else:
                heapq.heappush(ready[dev_of(unit[1])], (priority(unit), unit))
        row: list[tuple[int, int, int] | None] = [None] * d_
        for dev in range(d_):
            skipped = []
            unit = None
            while ready[dev]:
                cand = heapq.heappop(ready[dev])
                if (
                    cand[1][0] == UNIT_F
                    and not gpipe_gated
                    and live[dev][cand[1][1] // d_] >= cap
                ):
                    skipped.append(cand)
                    continue
                unit = cand[1]
                break
            for c in skipped:
                heapq.heappush(ready[dev], c)
            row[dev] = unit
        for dev, unit in enumerate(row):
            if unit is None:
                continue
            kind, p, m = unit
            finish[unit] = t
            remaining -= 1
            if kind == UNIT_F:
                remaining_f -= 1
                live[dev][p // d_] += 1
                if p < p_last:
                    becomes_ready.setdefault(t + handoff, []).append(
                        (UNIT_F, p + 1, m)
                    )
                else:
                    becomes_ready.setdefault(t + 1, []).append((UNIT_B, p, m))
            elif kind == UNIT_B:
                if p > 0:
                    becomes_ready.setdefault(t + handoff, []).append(
                        (UNIT_B, p - 1, m)
                    )
                if split_backward:
                    becomes_ready.setdefault(t + 1, []).append((UNIT_W, p, m))
                else:
                    live[dev][p // d_] -= 1
            else:  # UNIT_W
                live[dev][p // d_] -= 1
        if gpipe_gated and remaining_f == 0 and deferred_b:
            for unit in deferred_b:
                becomes_ready.setdefault(t + 1, []).append(unit)
            deferred_b = []
        work.append(row)
        t += 1
        if t > limit:  # pragma: no cover
            raise RuntimeError(f"combined schedule {name} did not converge")

    num_ticks = len(work)
    kind_tab = np.full((num_ticks, d_), -1, np.int32)
    microbatch = np.full((num_ticks, d_), -1, np.int32)
    position = np.zeros((num_ticks, d_), np.int32)
    for tt, row in enumerate(work):
        for dev, unit in enumerate(row):
            if unit is None:
                continue
            k, p, m = unit
            kind_tab[tt, dev] = k
            microbatch[tt, dev] = m
            position[tt, dev] = p

    # Stash coloring: the activation stashed by F(p, m) on dev(p) is
    # live through the tick its releasing unit (B, or W when split)
    # consumes it.  Same smallest-free interval allocation as the
    # hand-off slots, so the color count is exactly the peak.
    stash_slot = np.full((num_ticks, d_), -1, np.int32)
    release_slot = np.full((num_ticks, d_), -1, np.int32)
    free: list[list[int]] = [[] for _ in range(d_)]
    next_slot = [0] * d_
    freed: dict[tuple[int, int], list[int]] = {}
    slot_of: dict[tuple[int, int], int] = {}
    for tt, row in enumerate(work):
        for dev in range(d_):
            for slot in freed.pop((tt, dev), []):
                free[dev].append(slot)
        for dev, unit in enumerate(row):
            if unit is None:
                continue
            k, p, m = unit
            if k == UNIT_F:
                if free[dev]:
                    slot = min(free[dev])
                    free[dev].remove(slot)
                else:
                    slot = next_slot[dev]
                    next_slot[dev] += 1
                stash_slot[tt, dev] = slot
                slot_of[(p, m)] = slot
            elif k == release_kind:
                slot = slot_of.pop((p, m))
                release_slot[tt, dev] = slot
                freed.setdefault((tt + 1, dev), []).append(slot)

    return CombinedPlan(
        name=name,
        num_stages=d_,
        num_microbatches=m_,
        interleave=v_,
        handoff=handoff,
        split_backward=split_backward,
        num_ticks=num_ticks,
        kind=kind_tab,
        microbatch=microbatch,
        position=position,
        stash_slot=stash_slot,
        release_slot=release_slot,
        num_stash_slots=max(next_slot) if max(next_slot) else 0,
        forward=build_plan(name, d_, m_, v_, handoff),
        backward=build_backward_plan(name, d_, m_, v_, handoff),
    )
