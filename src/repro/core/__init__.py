"""Core: the paper's Stream-with-Future construct, in JAX.

Public API:
  StreamProgram, LazyEvaluator, FutureEvaluator, evaluate
  Future, defer, HostFuture, collective futures
  ChunkPolicy, bubble_fraction, optimal_num_chunks
  PipelineConfig, pipeline_apply
"""
from repro.core.chunking import (
    ChunkPolicy,
    bubble_fraction,
    chunk_axis,
    optimal_num_chunks,
    pipeline_step_time,
    unchunk_axis,
)
from repro.core.future import (
    Future,
    HostFuture,
    all_gather_future,
    defer,
    ppermute_future,
    psum_scatter_future,
)
from repro.core.pipeline import (
    PipelineConfig,
    merge_stages,
    pipeline_apply,
    split_stages,
)
from repro.core.stream import (
    FutureEvaluator,
    LazyEvaluator,
    StreamProgram,
    evaluate,
)

__all__ = [
    "ChunkPolicy",
    "Future",
    "FutureEvaluator",
    "HostFuture",
    "LazyEvaluator",
    "PipelineConfig",
    "StreamProgram",
    "all_gather_future",
    "bubble_fraction",
    "chunk_axis",
    "defer",
    "evaluate",
    "merge_stages",
    "optimal_num_chunks",
    "pipeline_apply",
    "pipeline_step_time",
    "ppermute_future",
    "psum_scatter_future",
    "split_stages",
    "unchunk_axis",
]
