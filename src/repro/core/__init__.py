"""Core: the paper's Stream-with-Future construct, in JAX.

Public API:
  StreamProgram, LazyEvaluator, FutureEvaluator, evaluate
  Future, defer, HostFuture, collective futures
  SchedulePlan, build_plan (the schedule zoo: gpipe / one_f_one_b /
  interleaved)
  ChunkPolicy, bubble_fraction, optimal_num_chunks, optimal_schedule
  PipelineConfig, pipeline_apply
"""
from repro.core.chunking import (
    ChunkPolicy,
    ScheduleChoice,
    bubble_fraction,
    chunk_axis,
    optimal_num_chunks,
    optimal_schedule,
    pipeline_step_time,
    schedule_bubble_fraction,
    schedule_peak_items,
    schedule_ticks,
    unchunk_axis,
)
from repro.core.schedules import SCHEDULES, SchedulePlan, build_plan
from repro.core.future import (
    Future,
    HostFuture,
    all_gather_future,
    defer,
    ppermute_future,
    psum_scatter_future,
)
from repro.core.pipeline import (
    PipelineConfig,
    merge_stages,
    pipeline_apply,
    split_stages,
)
from repro.core.stream import (
    FutureEvaluator,
    LazyEvaluator,
    StreamProgram,
    evaluate,
)

__all__ = [
    "ChunkPolicy",
    "Future",
    "FutureEvaluator",
    "HostFuture",
    "LazyEvaluator",
    "PipelineConfig",
    "SCHEDULES",
    "ScheduleChoice",
    "SchedulePlan",
    "StreamProgram",
    "all_gather_future",
    "bubble_fraction",
    "build_plan",
    "chunk_axis",
    "defer",
    "evaluate",
    "merge_stages",
    "optimal_num_chunks",
    "optimal_schedule",
    "pipeline_apply",
    "pipeline_step_time",
    "ppermute_future",
    "psum_scatter_future",
    "schedule_bubble_fraction",
    "schedule_peak_items",
    "schedule_ticks",
    "split_stages",
    "unchunk_axis",
]
