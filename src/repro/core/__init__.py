"""Core: the paper's Stream-with-Future construct, in JAX.

Public API:
  Stream, StreamResult — the combinator algebra front door:
    Stream.source(items).map(f).through(cell_fn, states)
          .zip(other, combine).concat(other).mask(pred)
          .collect(evaluator)
    Stream.feedback(init, n, emit) — the unfold combinator: item b
          re-enters as emit(item b - lag); persistent feedback plans
          (schedules.build_plan(feedback_lag=...)) pipeline it
  LazyEvaluator, FutureEvaluator, evaluate — the substitutable monads
  StreamGraph IR internals (repro.core.graph): lower_chain, ChainProgram
  StreamProgram — deprecated single-chain adapter; migrate via
    Stream.from_program(program, items) (see the stream.py migration
    note) — multi-source programs have no StreamProgram spelling
  Future, defer, HostFuture, collective futures
  SchedulePlan, build_plan (the schedule zoo: gpipe / one_f_one_b /
  interleaved; multi-source feed carousels via inject_positions)
  CombinedPlan, build_combined_plan, build_backward_plan — training
  backward as first-class scheduled units (true 1F1B; executed by
  FutureEvaluator(backward="planned"), modes in BACKWARD_MODES)
  ChunkPolicy, bubble_fraction, optimal_num_chunks, optimal_schedule
  PipelineConfig, pipeline_apply
"""
from repro.core.chunking import (
    ChunkPolicy,
    ScheduleChoice,
    bubble_fraction,
    chunk_axis,
    feed_peak_items,
    optimal_num_chunks,
    optimal_schedule,
    pipeline_step_time,
    schedule_bubble_fraction,
    schedule_peak_items,
    schedule_ticks,
    unchunk_axis,
)
from repro.core.graph import (
    ChainProgram,
    Stream,
    StreamResult,
    lower_chain,
)
from repro.core.schedules import (
    BACKWARD_MODES,
    SCHEDULES,
    CombinedPlan,
    SchedulePlan,
    build_backward_plan,
    build_combined_plan,
    build_plan,
)
from repro.core.future import (
    Future,
    HostFuture,
    all_gather_future,
    defer,
    ppermute_future,
    psum_scatter_future,
)
from repro.core.pipeline import (
    PipelineConfig,
    merge_stages,
    pipeline_apply,
    split_stages,
)
from repro.core.stream import (
    FutureEvaluator,
    LazyEvaluator,
    StreamProgram,
    evaluate,
)

__all__ = [
    "BACKWARD_MODES",
    "ChainProgram",
    "ChunkPolicy",
    "CombinedPlan",
    "Future",
    "FutureEvaluator",
    "HostFuture",
    "LazyEvaluator",
    "PipelineConfig",
    "SCHEDULES",
    "ScheduleChoice",
    "SchedulePlan",
    "Stream",
    "StreamProgram",
    "StreamResult",
    "all_gather_future",
    "bubble_fraction",
    "build_backward_plan",
    "build_combined_plan",
    "build_plan",
    "chunk_axis",
    "defer",
    "evaluate",
    "feed_peak_items",
    "lower_chain",
    "merge_stages",
    "optimal_num_chunks",
    "optimal_schedule",
    "pipeline_apply",
    "pipeline_step_time",
    "ppermute_future",
    "psum_scatter_future",
    "schedule_bubble_fraction",
    "schedule_peak_items",
    "schedule_ticks",
    "split_stages",
    "unchunk_axis",
]
