"""Chunk-size policy — the paper's §7 proposal, implemented.

The paper's evaluation found that fine-grained stream cells do not scale
("the minimum size of elementary computations seems to be a key factor")
and proposed *grouping these in bigger chunks* as future work.  On a TPU
pipeline the trade-off is exact:

* With S stages and M chunks (microbatches), the fill/drain bubble wastes
  ``(S-1)/(M+S-1)`` of the schedule — more chunks amortize it.
* Each chunk pays a fixed per-cell overhead ``c`` (dispatch, collective
  latency, kernel launch on GPU / loop control on TPU); fewer, bigger
  chunks amortize *that*.
* Per-stage memory holds ``O(chunk_bytes)`` in-flight buffers, bounding
  chunk size from above (VMEM/HBM budget).

``optimal_num_chunks`` minimizes the modeled step time; it reproduces the
paper's qualitative finding (their ``primes`` cells were far below the
break-even size) and quantifies it.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


def bubble_fraction(num_stages: int, num_chunks: int) -> float:
    """Fill/drain bubble fraction of a linear pipeline (GPipe forward)."""
    if num_stages <= 1:
        return 0.0
    return (num_stages - 1) / (num_chunks + num_stages - 1)


def pipeline_step_time(
    work_per_item: float,
    num_stages: int,
    num_chunks: int,
    per_tick_overhead: float,
) -> float:
    """Modeled wall time of pipelining `work_per_item` split into chunks.

    ``work_per_item`` is the total serial compute time of one full item
    through all stages; each of the (M + S - 1) ticks costs the slowest
    stage's chunk compute (work / (S*M)) plus a fixed overhead.
    """
    ticks = num_chunks + num_stages - 1
    per_tick_compute = work_per_item / (num_stages * num_chunks)
    return ticks * (per_tick_compute + per_tick_overhead)


def optimal_num_chunks(
    work_per_item: float,
    num_stages: int,
    per_tick_overhead: float,
    max_chunks: int = 4096,
) -> int:
    """Minimize modeled step time over the number of chunks M.

    Closed form of d/dM [ (M+S-1)(W/(S·M) + c) ] = 0:
        M* = sqrt( W (S-1) / (S c) )
    clipped to [1, max_chunks].  When overhead dominates (paper's primes
    case) M* -> 1: don't pipeline fine-grained work.
    """
    if num_stages <= 1 or per_tick_overhead <= 0:
        return max_chunks
    m_star = math.sqrt(
        work_per_item * (num_stages - 1) / (num_stages * per_tick_overhead)
    )
    return max(1, min(max_chunks, round(m_star)))


@dataclasses.dataclass(frozen=True)
class ChunkPolicy:
    """Static chunking decision for a stream axis (items or sequence)."""

    num_chunks: int
    chunk_size: int

    @staticmethod
    def for_axis(axis_len: int, num_chunks: int) -> "ChunkPolicy":
        if axis_len % num_chunks != 0:
            raise ValueError(f"{axis_len=} not divisible by {num_chunks=}")
        return ChunkPolicy(num_chunks, axis_len // num_chunks)


def chunk_axis(tree, num_chunks: int, axis: int = 0):
    """Reshape leading `axis` of every leaf into (num_chunks, chunk, ...)."""

    def _chunk(x):
        if x.shape[axis] % num_chunks != 0:
            raise ValueError(
                f"axis {axis} of shape {x.shape} not divisible by {num_chunks}"
            )
        new_shape = (
            x.shape[:axis]
            + (num_chunks, x.shape[axis] // num_chunks)
            + x.shape[axis + 1 :]
        )
        x = x.reshape(new_shape)
        if axis != 0:
            x = jnp.moveaxis(x, axis, 0)
        return x

    return jax.tree.map(_chunk, tree)


def unchunk_axis(tree, axis: int = 0):
    """Inverse of :func:`chunk_axis`."""

    def _unchunk(x):
        if axis != 0:
            x = jnp.moveaxis(x, 0, axis)
        new_shape = x.shape[:axis] + (-1,) + x.shape[axis + 2 :]
        return x.reshape(new_shape)

    return jax.tree.map(_unchunk, tree)
