"""Chunk-size policy — the paper's §7 proposal, implemented.

The paper's evaluation found that fine-grained stream cells do not scale
("the minimum size of elementary computations seems to be a key factor")
and proposed *grouping these in bigger chunks* as future work.  On a TPU
pipeline the trade-off is exact:

* With S stages and M chunks (microbatches), the fill/drain bubble wastes
  ``(S-1)/(M+S-1)`` of the schedule — more chunks amortize it.
* Each chunk pays a fixed per-cell overhead ``c`` (dispatch, collective
  latency, kernel launch on GPU / loop control on TPU); fewer, bigger
  chunks amortize *that*.
* Per-stage memory holds ``O(chunk_bytes)`` in-flight buffers, bounding
  chunk size from above (VMEM/HBM budget).

``optimal_num_chunks`` minimizes the modeled step time; it reproduces the
paper's qualitative finding (their ``primes`` cells were far below the
break-even size) and quantifies it.

The model is schedule-aware (see :mod:`repro.core.schedules`): tick
counts, bubble fractions and peak in-flight memory are parameterized by
(schedule, interleave, handoff), and :func:`optimal_schedule` picks the
(schedule, M, V) triple jointly under an optional memory budget.  The
closed-form tick count

    T = (V - 1) * max(M, h*S) + M + h*(S - 1)

is exact against the greedy plans ``schedules.build_plan`` emits (tested
over the full grid); ``h`` is the hand-off latency — 1 for a textbook
synchronous pipeline, 2 for the evaluator's issue-early/force-late ring.

Multi-injection plans (multi-source ``zip`` streams) leave ticks and
bubble untouched — injections only add feed columns — but they do cost
memory: :func:`feed_peak_items` models each source's round-robin shard
plus carousel register, :func:`schedule_peak_items` charges extra
sources against the activation stash, and :func:`optimal_schedule`
takes ``num_sources`` so the budget constraint sees the feeds.

The peak-memory term is parameterized by the backward mode
(``backward="planned" | "autodiff"``): under the planned backward
(:func:`repro.core.schedules.build_combined_plan` executed by
``FutureEvaluator(backward="planned")``) each schedule's stash bound —
1F1B's ``min(S, M)`` — is measured from the combined plan's
stash/release columns, not assumed; it is the schedule-level bound a
fused executor realizes (the shipped two-phase custom-VJP realization
still holds ``V*M`` at the XLA autodiff phase boundary — see
``CombinedPlan``).  Autodiff training keeps every unit input live
regardless of schedule, so all schedules cost ``V*M`` and a memory
budget cannot prefer one.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.schedules import (
    DEFAULT_HANDOFF,
    feed_items_per_source,
    peak_inflight_items,
    validate_schedule,
)


def bubble_fraction(num_stages: int, num_chunks: int) -> float:
    """Fill/drain bubble fraction of a linear pipeline (GPipe forward)."""
    if num_stages <= 1:
        return 0.0
    return (num_stages - 1) / (num_chunks + num_stages - 1)


def schedule_ticks(
    schedule: str,
    num_stages: int,
    num_chunks: int,
    interleave: int = 1,
    handoff: int = DEFAULT_HANDOFF,
) -> int:
    """Tick count of ``schedule`` — matches ``build_plan(...).num_ticks``.

    ``num_stages`` is the *device* count S of the pipeline axis; the
    interleaved schedule runs S*V virtual stages.  Exact for S >= 2 (and
    for V == 1 always); the degenerate S == 1, V > 1 self-ring is not
    modeled.
    """
    v = validate_schedule(schedule, interleave)
    s, m, h = num_stages, num_chunks, handoff
    if s <= 1:
        return v * m
    return (v - 1) * max(m, h * s) + m + h * (s - 1)


def schedule_bubble_fraction(
    schedule: str,
    num_stages: int,
    num_chunks: int,
    interleave: int = 1,
    handoff: int = DEFAULT_HANDOFF,
) -> float:
    """Idle fraction of the (ticks x stages) grid under ``schedule``.

    Interleaving divides per-tick work by V while fill/drain stays
    ``h*(S-1)`` ticks, so the bubble falls from ``h(S-1)/(M + h(S-1))``
    to ``h(S-1)/(V*M + h(S-1))`` — the engine's reason to exist.
    """
    v = validate_schedule(schedule, interleave)
    if num_stages <= 1:
        return 0.0
    ticks = schedule_ticks(schedule, num_stages, num_chunks, interleave, handoff)
    return 1.0 - (v * num_chunks) / ticks


def schedule_peak_items(
    schedule: str,
    num_stages: int,
    num_chunks: int,
    interleave: int = 1,
    num_sources: int = 1,
    backward: str = "planned",
) -> int:
    """Peak per-device activation stash (in microbatches) — the
    schedule's memory term (delegates to the single definition in
    :mod:`repro.core.schedules`).

    ``backward="planned"`` (default) is the combined plan's own peak —
    the *schedule-level* bound proven by its stash/release columns,
    realized in full by a fused executor (the shipped two-phase
    custom-VJP realization still holds all ``V*M`` stashes at the XLA
    fwd/bwd phase boundary; see
    :class:`repro.core.schedules.CombinedPlan`); ``backward="autodiff"``
    charges the ``V*M`` that transposing the forward scan keeps live
    for *every* schedule.  ``num_sources >
    1`` adds the extra sources' feed storage (multi-injection plans:
    one round-robin shard plus one carousel register per extra
    source)."""
    return peak_inflight_items(
        schedule, num_stages, num_chunks, interleave, num_sources, backward
    )


def feed_peak_items(
    num_stages: int, num_chunks: int, num_sources: int = 1
) -> int:
    """Per-device item-feed storage of a multi-injection plan, in items.

    Each source keeps its local round-robin shard (``ceil(M/S)`` items)
    plus the one in-flight carousel register that rotates on the reverse
    ring.  Tick count and bubble are *unchanged* by extra injections —
    the plan tables are position-oblivious (tested against
    ``build_plan(..., inject_positions=...)``); feeds are the only term
    that scales with source count.
    """
    if num_sources < 1 or num_stages < 1 or num_chunks < 1:
        raise ValueError(
            f"need num_sources/num_stages/num_chunks >= 1, got "
            f"{num_sources}/{num_stages}/{num_chunks}"
        )
    return num_sources * feed_items_per_source(num_stages, num_chunks)


def pipeline_step_time(
    work_per_item: float,
    num_stages: int,
    num_chunks: int,
    per_tick_overhead: float,
    schedule: str = "gpipe",
    interleave: int = 1,
    handoff: int = 1,
    per_tick_copy: float = 0.0,
) -> float:
    """Modeled wall time of pipelining `work_per_item` split into chunks.

    ``work_per_item`` is the total serial compute time of one full item
    through all stages; each tick costs the slowest stage's group compute
    (``work / (S*M*V)``) plus a fixed overhead.  The default
    (gpipe, V=1, h=1) reproduces the classic ``(M+S-1)(W/(S M) + c)``;
    pass ``handoff=schedules.DEFAULT_HANDOFF`` to model the Future
    engine's overlapped ring (whose per-tick overhead is what is left
    after the permute hides under the cell scan).

    ``per_tick_copy`` is the mutable-state traffic term: the time a tick
    spends writing per-cell state back (KV-cache updates for a serving
    chain — see :func:`copy_time_per_tick` for the bytes→time
    conversion).  It is kept separate from ``per_tick_overhead`` because
    it scales with the *state update scheme* (a whole-slab write-back
    per microbatch is ``max_len``× a row-level scatter), which is how
    the model distinguishes the two serving hot paths.
    """
    v = validate_schedule(schedule, interleave)
    ticks = schedule_ticks(schedule, num_stages, num_chunks, interleave, handoff)
    per_tick_compute = work_per_item / (num_stages * num_chunks * v)
    return ticks * (per_tick_compute + per_tick_overhead + per_tick_copy)


def copy_time_per_tick(
    copy_bytes_per_tick: float, copy_bytes_per_second: float
) -> float:
    """Bytes a tick writes back into mutable per-cell state → seconds.

    The single conversion site for the copy-bytes term: callers (the
    serving engine's :func:`repro.serve.engine.decode_copy_bytes_per_tick`)
    supply measured/modeled bytes and the device's effective write
    bandwidth.
    """
    if copy_bytes_per_second <= 0:
        raise ValueError(
            f"copy_bytes_per_second must be > 0, got {copy_bytes_per_second}"
        )
    return copy_bytes_per_tick / copy_bytes_per_second


def optimal_num_chunks(
    work_per_item: float,
    num_stages: int,
    per_tick_overhead: float,
    max_chunks: int = 4096,
    schedule: str = "gpipe",
    interleave: int = 1,
    handoff: int = 1,
    per_tick_copy: float = 0.0,
) -> int:
    """Minimize modeled step time over the number of chunks M.

    Closed form of d/dM [ (VM + h(S-1))(W/(S·M·V) + c) ] = 0:
        M* = sqrt( h W (S-1) / (S c) ) / V
    (gpipe, h=1 reduces to the paper-era ``sqrt(W(S-1)/(S c))``),
    refined by evaluating integer neighbors so the kink at M = h*S in
    the interleaved tick count is respected.  Clipped to
    [1, max_chunks].  When overhead dominates (paper's primes case)
    M* -> 1: don't pipeline fine-grained work.  ``per_tick_copy`` joins
    ``c`` in the closed form (both are fixed per-tick costs), so heavy
    state write-back pushes toward fewer, bigger chunks — and shrinking
    it (the row-scatter path) buys chunks back.
    """
    v = validate_schedule(schedule, interleave)
    per_tick_fixed = per_tick_overhead + per_tick_copy
    if num_stages <= 1 or per_tick_fixed <= 0:
        return max_chunks
    m_star = (
        math.sqrt(
            handoff
            * work_per_item
            * (num_stages - 1)
            / (num_stages * per_tick_fixed)
        )
        / v
    )
    candidates = {
        max(1, min(max_chunks, m))
        for m in (
            math.floor(m_star),
            math.ceil(m_star),
            handoff * num_stages,
            1,
            max_chunks,
        )
        if m >= 1
    }
    return min(
        candidates,
        key=lambda m: (
            pipeline_step_time(
                work_per_item,
                num_stages,
                m,
                per_tick_overhead,
                schedule,
                interleave,
                handoff,
                per_tick_copy,
            ),
            m,
        ),
    )


@dataclasses.dataclass(frozen=True)
class ScheduleChoice:
    """Joint (schedule, M, V) decision from :func:`optimal_schedule`."""

    schedule: str
    num_chunks: int
    interleave: int
    modeled_time: float
    bubble: float
    peak_items: int


def optimal_schedule(
    work_per_item: float,
    num_stages: int,
    per_tick_overhead: float,
    *,
    max_chunks: int = 4096,
    interleave_options: tuple[int, ...] = (1, 2, 4),
    memory_budget_items: float | None = None,
    handoff: int = DEFAULT_HANDOFF,
    num_sources: int = 1,
    chunks_divide: int | None = None,
    backward: str = "autodiff",
    per_tick_copy: float = 0.0,
) -> ScheduleChoice:
    """Pick (schedule, M, V) jointly: minimize modeled step time subject
    to a peak-activation budget.

    ``per_tick_copy`` is the per-tick mutable-state write-back time (see
    :func:`pipeline_step_time` / :func:`copy_time_per_tick`) — the
    serving engines' copy-bytes term.  Because it is a fixed tick cost,
    it penalizes exactly the schedules that multiply tick count
    (interleaving's V× ticks buy less when every tick pays the copy),
    which is why the joint pick must see it.

    ``memory_budget_items`` caps ``schedule_peak_items(...) / M`` — peak
    stash measured in units of the *whole* item's activation footprint
    (gpipe always costs exactly 1.0; 1F1B costs S/M once M > S, which is
    how it buys bigger M under a budget).  ``None`` means unconstrained.
    ``backward`` selects whose stash is scored, and must match the
    job's actual execution mode.  ``"autodiff"`` (default — matching
    ``TrainConfig.pipeline_backward``) charges every schedule the full
    ``V*M`` the scan transpose keeps live, under which no schedule buys
    memory and a tight budget is simply infeasible — the honest answer
    for a default-configured job.  ``"planned"`` scores each schedule's
    combined-plan peak — 1F1B's ``min(S, M)`` advantage, real under
    ``FutureEvaluator(backward="planned")``.  (The *descriptive*
    :func:`schedule_peak_items` keeps ``"planned"`` as its default: it
    characterizes the schedule itself; this function makes a decision
    against a budget, so it defaults conservative.)
    ``num_sources > 1`` charges multi-injection feed storage against the
    same budget (more sources push toward schedules that stash less).
    ``chunks_divide`` restricts M to divisors of it (a global batch must
    chunk evenly) — the constraint belongs *inside* the search, so the
    returned choice's M, modeled time and budget check all describe the
    schedule that actually runs.
    """
    grid: list[tuple[str, int]] = [("gpipe", 1), ("one_f_one_b", 1)]
    grid += [("interleaved", v) for v in interleave_options if v > 1]
    divisors = None
    if chunks_divide is not None:
        divisors = [
            d
            for d in range(1, min(chunks_divide, max_chunks) + 1)
            if chunks_divide % d == 0
        ]
    best: ScheduleChoice | None = None
    for name, v in grid:
        m0 = optimal_num_chunks(
            work_per_item, num_stages, per_tick_overhead, max_chunks, name, v,
            handoff, per_tick_copy,
        )
        # scan a neighborhood: the memory constraint may push M up past
        # the unconstrained optimum (more, smaller chunks stash less).
        seen = sorted(
            {
                max(1, min(max_chunks, m))
                for m in (
                    m0,
                    m0 // 2,
                    m0 * 2,
                    num_stages,
                    handoff * num_stages,
                    max_chunks,
                )
            }
        )
        if divisors is not None:
            # snap every candidate to its neighboring divisors
            snapped = set()
            for m in seen:
                snapped.add(max((d for d in divisors if d <= m), default=1))
                snapped.add(min((d for d in divisors if d >= m), default=divisors[-1]))
            seen = sorted(snapped)
        for m in seen:
            if memory_budget_items is not None:
                peak = (
                    schedule_peak_items(
                        name, num_stages, m, v, num_sources, backward
                    )
                    / m
                )
                if peak > memory_budget_items:
                    continue
            t = pipeline_step_time(
                work_per_item, num_stages, m, per_tick_overhead, name, v,
                handoff, per_tick_copy,
            )
            cand = ScheduleChoice(
                schedule=name,
                num_chunks=m,
                interleave=v,
                modeled_time=t,
                bubble=schedule_bubble_fraction(name, num_stages, m, v, handoff),
                peak_items=schedule_peak_items(
                    name, num_stages, m, v, num_sources, backward
                ),
            )
            if best is None or cand.modeled_time < best.modeled_time:
                best = cand
    if best is None:
        raise ValueError(
            "no (schedule, M) fits memory_budget_items="
            f"{memory_budget_items} at num_stages={num_stages}"
        )
    return best


@dataclasses.dataclass(frozen=True)
class ChunkPolicy:
    """Static chunking decision for a stream axis (items or sequence)."""

    num_chunks: int
    chunk_size: int

    @staticmethod
    def for_axis(axis_len: int, num_chunks: int) -> "ChunkPolicy":
        if axis_len % num_chunks != 0:
            raise ValueError(f"{axis_len=} not divisible by {num_chunks=}")
        return ChunkPolicy(num_chunks, axis_len // num_chunks)


def chunk_axis(tree, num_chunks: int, axis: int = 0):
    """Reshape leading `axis` of every leaf into (num_chunks, chunk, ...)."""

    def _chunk(x):
        if x.shape[axis] % num_chunks != 0:
            raise ValueError(
                f"axis {axis} of shape {x.shape} not divisible by {num_chunks}"
            )
        new_shape = (
            x.shape[:axis]
            + (num_chunks, x.shape[axis] // num_chunks)
            + x.shape[axis + 1 :]
        )
        x = x.reshape(new_shape)
        if axis != 0:
            x = jnp.moveaxis(x, axis, 0)
        return x

    return jax.tree.map(_chunk, tree)


def unchunk_axis(tree, axis: int = 0):
    """Inverse of :func:`chunk_axis`."""

    def _unchunk(x):
        if axis != 0:
            x = jnp.moveaxis(x, 0, axis)
        new_shape = x.shape[:axis] + (-1,) + x.shape[axis + 2 :]
        return x.reshape(new_shape)

    return jax.tree.map(_unchunk, tree)
