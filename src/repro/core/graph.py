"""Stream combinator algebra and the StreamGraph IR.

The paper's claim is that *any* algorithm expressible as a Stream
computation parallelizes by monad substitution.  Real Stream programs
compose — the paper's own examples are written with ``map``/``filter``/
``zip``-style combinators — so the public front door is an algebra, not a
single linear chain:

    Stream.source(items)            # a bounded stream of M items
          .map(f)                   # stateless per-item transform
          .through(cell_fn, states) # a chain segment of dependent cells
          .zip(other, combine)      # merge two streams item-by-item
          .concat(other)            # one stream after another
          .mask(pred)               # bounded-stream validity tagging
          .collect(evaluator)       # run it

    Stream.feedback(init, n, emit)  # a self-feeding (unfold) source:
          .through(cell_fn, states) # item b >= lag re-enters as
          .collect(evaluator)       # emit(item b-lag after the chain)

Combinators build a typed **StreamGraph IR** — a DAG of
:class:`SourceNode` / :class:`MapNode` / :class:`SegmentNode` /
:class:`ZipNode` / :class:`ConcatNode` / :class:`MaskNode` /
:class:`FeedbackNode` — validated at construction (item counts, state
shapes, pytree structure for ``concat``).

``Stream.feedback`` is the unfold/feedback combinator: the stream's
item ``b`` (for ``b >= lag``) is not read from a source — it is
``emit(o)`` where ``o`` is item ``b - lag``'s output *after the whole
downstream chain*.  This is what a serving decode loop is: the sampled
token re-enters as the next item, KV-cache rows ride in the chain's
per-cell state, and ``lag`` (the number of in-flight microbatches)
is what keeps a pipeline of dependent steps busy.  Feedback graphs
have no node-local evaluation order, so :func:`lazy_eval_graph`
rejects them; both evaluators run them through the lowered
:class:`ChainProgram` (:func:`run_chain_sequential` is the sequential
reference executor).
Adjacent ``map``s fuse at construction (``s.map(f).map(g)`` builds the
same one-node IR as ``s.map(g ∘ f)``), the first of the algebra's laws
tested in ``tests/test_stream_algebra.py``.

Two execution paths share the IR:

* :func:`lazy_eval_graph` — the Lazy monad: topological composition of
  ``lax.scan``s, one per node.  Runs *any* well-formed graph, including
  zips whose both sides carry stateful segments.
* :func:`lower_chain` — compiles the graph into a :class:`ChainProgram`
  (fused chain segments + per-source injection points) that
  :class:`repro.core.stream.FutureEvaluator` pipelines across devices.
  Supported graphs are those in *spine normal form*: one trunk of
  segments, where every ``zip`` merges in a stateless branch (source +
  maps).  A ``zip`` of two stateful pipelines has no linear-pipeline
  realization; lowering raises with a pointer to ``LazyEvaluator``.

Push-fusion of stateless stages into their consumers is the classic
stream-API optimization (Clash of the Lambdas, arXiv 1406.6631); the
deterministic merge semantics of ``zip``/``concat`` follow the
stream-ordering discipline of arXiv 2504.02975 — item *b* of a zip is
``combine(left[b], right[b])``, independent of evaluator or schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any
CellFn = Callable[[PyTree, PyTree], tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# Validation helpers
# ---------------------------------------------------------------------------


def leading_axis_size(items: PyTree, what: str = "items") -> int:
    """Common leading-axis length of every leaf, with clear errors.

    Raises ``ValueError`` on an empty pytree or on leaves that disagree
    about the leading axis (the stream length M must be unambiguous).
    """
    leaves = jax.tree.leaves(items)
    if not leaves:
        raise ValueError(f"{what} is an empty pytree; a stream needs >= 1 leaf")
    sizes = set()
    for leaf in leaves:
        shape = getattr(leaf, "shape", None)
        if not shape:
            raise ValueError(
                f"{what} leaves must be arrays with a leading stream axis; "
                f"got scalar leaf {leaf!r}"
            )
        sizes.add(shape[0])
    if len(sizes) != 1:
        raise ValueError(
            f"{what} leaves disagree on the leading (stream) axis: sizes "
            f"{sorted(sizes)}; every leaf must have the same number of items"
        )
    return sizes.pop()


def _tree_structure(items: PyTree):
    return jax.tree.structure(items)


def _check_concat_structures(lv: PyTree, rv: PyTree) -> None:
    if _tree_structure(lv) != _tree_structure(rv):
        raise ValueError(
            "concat requires both streams to share one item pytree "
            f"structure, got {_tree_structure(lv)} vs {_tree_structure(rv)}"
        )


def _concat_items(lv: PyTree, rv: PyTree) -> PyTree:
    """Leaf-wise leading-axis concatenation, with the one shared error."""
    _check_concat_structures(lv, rv)
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), lv, rv)


def _item_skeleton(node: "Node") -> PyTree | None:
    """A zero-filled pytree with the node's per-item structure, when it is
    statically derivable (sources, masks, concats); ``None`` once a user
    function (map/zip/segment) whose output structure we cannot know
    intervenes."""
    if isinstance(node, SourceNode):
        return jax.tree.map(lambda _: 0, node.items)
    if isinstance(node, MaskNode):
        up = _item_skeleton(node.upstream)
        return None if up is None else {"valid": 0, "value": up}
    if isinstance(node, ConcatNode):
        return _item_skeleton(node.left)  # sides validated at construction
    return None


def apply_per_item(fn: Callable[[PyTree], PyTree], items: PyTree) -> PyTree:
    """Apply a per-item ``fn`` across the leading stream axis.

    ``lax.map`` (a scan), not ``vmap``: both evaluators apply per-item
    transforms with the same primitive sequence per item, which is what
    makes Lazy ≡ Future *bit*-equality hold for fused maps.
    """
    return lax.map(fn, items)


# ---------------------------------------------------------------------------
# IR nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class Node:
    """Base IR node; identity (not structure) keyed, so graphs are DAGs."""


@dataclasses.dataclass(frozen=True, eq=False)
class SourceNode(Node):
    items: PyTree
    num_items: int


@dataclasses.dataclass(frozen=True, eq=False)
class MapNode(Node):
    fn: Callable[[PyTree], PyTree]
    upstream: Node


@dataclasses.dataclass(frozen=True, eq=False)
class MaskNode(Node):
    """Bounded-stream validity: item -> {"value": item, "valid": pred(item)}.

    Unbounded streams do not exist on XLA; validity masks are how bounded
    streams express "the tail past here is not real data".
    """

    pred: Callable[[PyTree], jnp.ndarray]
    upstream: Node


@dataclasses.dataclass(frozen=True, eq=False)
class SegmentNode(Node):
    """A chain segment: ``num_cells`` dependent cells with stacked state.

    ``const_state`` holds *read-only* per-cell leaves (layer parameters,
    admission payloads — anything the cells consult but never write).
    Evaluators thread it as scan ``xs`` only: it never enters a scan
    carry, a ``lax.cond`` output, or a per-tick state write-back, so it
    is never copied on the hot path.  With ``const_state`` given, the
    cell signature is ``cell_fn(const, state, item) -> (state', item')``.
    """

    cell_fn: CellFn
    init_state: PyTree
    num_cells: int
    mutable_state: bool
    remat: bool
    upstream: Node
    const_state: PyTree | None = None


@dataclasses.dataclass(frozen=True, eq=False)
class FeedbackNode(Node):
    """A self-feeding source: the unfold combinator.

    The first ``lag`` items are ``init_items``; item ``b >= lag`` is
    ``emit(out[b - lag])`` where ``out[j]`` is item ``j``'s value after
    the *entire* downstream chain.  ``emit`` must preserve the flowing
    item structure (the fed-back value travels the same shape-static
    ring buffers as every inter-cell hand-off), and the emitted item is
    also the collected output item — under feedback the stream's
    outputs *are* what re-enters it.
    """

    init_items: PyTree
    num_items: int
    lag: int
    emit: Callable[[PyTree], PyTree]


@dataclasses.dataclass(frozen=True, eq=False)
class ZipNode(Node):
    left: Node
    right: Node
    combine: Callable[[PyTree, PyTree], PyTree]


@dataclasses.dataclass(frozen=True, eq=False)
class ConcatNode(Node):
    left: Node
    right: Node


def topo_nodes(sink: Node) -> list[Node]:
    """All nodes reachable from ``sink``, dependencies first."""
    order: list[Node] = []
    seen: set[int] = set()

    def visit(node: Node):
        if id(node) in seen:
            return
        seen.add(id(node))
        for dep in _inputs(node):
            visit(dep)
        order.append(node)

    visit(sink)
    return order


def _inputs(node: Node) -> tuple[Node, ...]:
    if isinstance(node, (MapNode, MaskNode, SegmentNode)):
        return (node.upstream,)
    if isinstance(node, (ZipNode, ConcatNode)):
        return (node.left, node.right)
    return ()


def _num_items(node: Node) -> int:
    if isinstance(node, SourceNode):
        return node.num_items
    if isinstance(node, FeedbackNode):
        return node.num_items
    if isinstance(node, (MapNode, MaskNode, SegmentNode)):
        return _num_items(node.upstream)
    if isinstance(node, ZipNode):
        return _num_items(node.left)
    if isinstance(node, ConcatNode):
        return _num_items(node.left) + _num_items(node.right)
    raise TypeError(f"unknown node {node!r}")


# ---------------------------------------------------------------------------
# The algebra
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """What :meth:`Stream.collect` returns.

    Attributes:
      items: the collected output items (leading axis = stream length).
      states: final per-segment states, in spine (upstream-to-downstream,
        left-to-right) order — one entry per ``.through`` in the program.
    """

    items: PyTree
    states: tuple[PyTree, ...]


class Stream:
    """A composable bounded stream — the algebra's handle onto the IR.

    Streams are immutable; every combinator returns a new ``Stream``
    sharing the upstream graph.  Nothing executes until
    :meth:`collect`.
    """

    def __init__(self, node: Node):
        self._node = node

    # -- constructors -------------------------------------------------------

    @staticmethod
    def source(items: PyTree) -> "Stream":
        """A stream of M items: every leaf's leading axis is the stream."""
        m = leading_axis_size(items, "source items")
        return Stream(SourceNode(items=items, num_items=m))

    @staticmethod
    def feedback(
        init_items: PyTree,
        num_items: int,
        emit: Callable[[PyTree], PyTree],
    ) -> "Stream":
        """A self-feeding stream (the unfold combinator).

        ``init_items`` (leading axis = ``lag``) are the first ``lag``
        inputs; item ``b >= lag`` is ``emit(out[b - lag])``, where
        ``out[j]`` is item ``j`` after the whole downstream chain.  The
        emitted item is also the collected output item, so ``emit`` must
        be structure-preserving on the flowing item.  ``lag`` is the
        feedback depth — for a pipelined decode loop, the number of
        independent in-flight microbatches that keeps the stages busy
        while each one's next step waits on its own previous output.
        """
        lag = leading_axis_size(init_items, "feedback init_items")
        if num_items < lag:
            raise ValueError(
                f"feedback num_items={num_items} must be >= lag={lag} "
                "(the init items are the first lag items of the stream)"
            )
        return Stream(
            FeedbackNode(
                init_items=init_items, num_items=num_items, lag=lag, emit=emit
            )
        )

    @staticmethod
    def from_program(program, items: PyTree) -> "Stream":
        """Adapter for the deprecated single-chain :class:`StreamProgram`.

        .. deprecated::
            Build the one-segment graph directly:
            ``Stream.source(items).through(p.cell_fn, p.init_state, ...)``.
        """
        import warnings

        warnings.warn(
            "Stream.from_program is deprecated; use "
            "Stream.source(items).through(cell_fn, init_state, ...)",
            DeprecationWarning,
            stacklevel=2,
        )
        return Stream.source(items).through(
            program.cell_fn,
            program.init_state,
            num_cells=program.num_cells,
            mutable_state=program.mutable_state,
            remat=program.remat,
        )

    # -- combinators --------------------------------------------------------

    def through(
        self,
        cell_fn: CellFn,
        init_state: PyTree,
        *,
        num_cells: int | None = None,
        mutable_state: bool = True,
        remat: bool = False,
        const_state: PyTree | None = None,
    ) -> "Stream":
        """A chain segment: ``num_cells`` dependent cells, item-ordered.

        ``cell_fn(state, item) -> (state', item')``; ``init_state`` leaves
        are stacked with leading axis ``num_cells`` (inferred when not
        given).  Segments compose back-to-back: ``s.through(f, a).through
        (g, b)`` is a longer chain, pipelined as one by the Future engine.

        ``const_state`` threads *read-only* per-cell leaves (leading axis
        ``num_cells``) to the cells as scan ``xs`` only — never written
        back, never carried, never copied per tick.  The cell signature
        becomes ``cell_fn(const, state, item) -> (state', item')``; final
        states returned by :meth:`collect` cover the mutable
        ``init_state`` only.  This is the read-only/mutable state split:
        layer parameters ride ``const_state``, the KV cache rides
        ``init_state``.
        """
        inferred = leading_axis_size(init_state, "init_state")
        if num_cells is None:
            num_cells = inferred
        elif inferred != num_cells:
            raise ValueError(
                f"init_state leaves must have leading axis num_cells="
                f"{num_cells}, got {inferred}"
            )
        if num_cells < 1:
            raise ValueError(f"num_cells must be >= 1, got {num_cells}")
        if const_state is not None:
            const_cells = leading_axis_size(const_state, "const_state")
            if const_cells != num_cells:
                raise ValueError(
                    f"const_state leaves must have leading axis num_cells="
                    f"{num_cells}, got {const_cells}"
                )
        return Stream(
            SegmentNode(
                cell_fn=cell_fn,
                init_state=init_state,
                num_cells=num_cells,
                mutable_state=mutable_state,
                remat=remat,
                upstream=self._node,
                const_state=const_state,
            )
        )

    def map(self, fn: Callable[[PyTree], PyTree]) -> "Stream":
        """Stateless per-item transform.  Adjacent maps fuse at
        construction: ``s.map(f).map(g)`` builds one ``MapNode`` computing
        ``g ∘ f`` — the same IR as ``s.map(lambda x: g(f(x)))``."""
        node = self._node
        if isinstance(node, MapNode):
            inner = node.fn
            fused = _compose(fn, inner)
            return Stream(MapNode(fn=fused, upstream=node.upstream))
        return Stream(MapNode(fn=fn, upstream=node))

    def mask(self, pred: Callable[[PyTree], jnp.ndarray]) -> "Stream":
        """Tag each item with validity: item -> {"value", "valid"}.

        The bounded-stream concession made explicit: downstream cells see
        which lanes are real.  ``pred`` maps an item to a boolean (or
        boolean array over the item's lanes)."""
        return Stream(MaskNode(pred=pred, upstream=self._node))

    def zip(
        self,
        other: "Stream",
        combine: Callable[[PyTree, PyTree], PyTree],
    ) -> "Stream":
        """Item-by-item merge of two equal-length streams.

        Deterministic by construction: item ``b`` of the result is
        ``combine(self[b], other[b])`` under every evaluator and schedule
        — parallel sources merge in source order, never arrival order."""
        m_l, m_r = _num_items(self._node), _num_items(other._node)
        if m_l != m_r:
            raise ValueError(
                f"zip requires equal stream lengths, got {m_l} vs {m_r}"
            )
        return Stream(ZipNode(left=self._node, right=other._node, combine=combine))

    def concat(self, other: "Stream") -> "Stream":
        """This stream's items, then ``other``'s.  Associative:
        ``(a ++ b) ++ c`` and ``a ++ (b ++ c)`` produce identical items."""
        ls, rs = _item_skeleton(self._node), _item_skeleton(other._node)
        if ls is not None and rs is not None:
            _check_concat_structures(ls, rs)
        return Stream(ConcatNode(left=self._node, right=other._node))

    # -- execution ----------------------------------------------------------

    @property
    def num_items(self) -> int:
        return _num_items(self._node)

    @property
    def num_cells(self) -> int:
        """Total chain length along the spine (0 for segment-free graphs)."""
        return sum(
            n.num_cells for n in topo_nodes(self._node) if isinstance(n, SegmentNode)
        )

    @property
    def node(self) -> Node:
        return self._node

    def nodes(self) -> list[Node]:
        """The IR, dependencies first (for inspection and law tests)."""
        return topo_nodes(self._node)

    def collect(self, evaluator=None) -> StreamResult:
        """Run the program.  ``None`` → the Lazy monad (sequential)."""
        if evaluator is None:
            from repro.core.stream import LazyEvaluator

            evaluator = LazyEvaluator()
        return evaluator.run_graph(self)

    def lower(self) -> "ChainProgram":
        """Compile to the linear-chain form the Future engine executes."""
        return lower_chain(self._node)


def _compose(outer, inner):
    return lambda item: outer(inner(item))


def _mask_fn(pred):
    return lambda item: {"value": item, "valid": pred(item)}


# ---------------------------------------------------------------------------
# Lazy execution: topological lax.scan composition
# ---------------------------------------------------------------------------


def _const_cell(cell_fn: CellFn, has_const: bool) -> CellFn:
    """Canonical 3-arg cell ``(const, state, item) -> (state', item')``.

    Segments without ``const_state`` get an adapter ignoring the (empty)
    const row, so every executor threads one signature: const rides scan
    ``xs``, state rides the carry/ys.
    """
    if has_const:
        return cell_fn
    return lambda _const, state, item: cell_fn(state, item)


def scan_cell(cell_fn: CellFn, mutable: bool):
    """The one cell-loop scan body every executor uses: carry = the
    flowing item, xs = ``(const_row, state_row)``, ys = the (possibly
    frozen) new state row.  A single definition site — Lazy ≡ Future
    bit-equality rests on the per-cell primitive sequence being
    identical, so the wrapper must never fork per executor."""

    def cell(flowing, xs):
        cst, state = xs
        new_state, out = cell_fn(cst, state, flowing)
        if not mutable:
            new_state = state
        return out, new_state

    return cell


def _run_segment(node: SegmentNode, items: PyTree) -> tuple[PyTree, PyTree]:
    """The Lazy monad on one segment: scan items (outer) over cells (inner).

    ``const_state`` (when present) is closed over and delivered per cell
    as inner-scan xs alongside the mutable rows — read-only by
    construction (no ys, no carry, no write-back)."""
    cell_fn = _const_cell(node.cell_fn, node.const_state is not None)
    if node.remat:
        cell_fn = jax.checkpoint(cell_fn)
    const = node.const_state  # None is an empty pytree: scans thread it
    cell = scan_cell(cell_fn, node.mutable_state)

    def item_step(states, item):
        out, new_states = lax.scan(cell, item, (const, states))
        return new_states, out

    return lax.scan(item_step, node.init_state, items)


def lazy_eval_graph(sink: Node) -> tuple[PyTree, tuple[PyTree, ...]]:
    """Execute the IR node-by-node in topological order.

    Returns ``(out_items, segment_final_states)`` with states ordered by
    the topological position of their ``SegmentNode``s.  Runs any
    well-formed graph — including zips of two stateful pipelines that the
    chain lowering rejects.
    """
    values: dict[int, PyTree] = {}
    seg_states: list[PyTree] = []
    for node in topo_nodes(sink):
        if isinstance(node, FeedbackNode):
            raise TypeError(
                "feedback graphs have no node-local evaluation order "
                "(item b depends on item b-lag through the whole chain); "
                "run them through the lowered ChainProgram — "
                "run_chain_sequential (Lazy) or FutureEvaluator"
            )
        if isinstance(node, SourceNode):
            leading_axis_size(node.items, "source items")
            values[id(node)] = node.items
        elif isinstance(node, MapNode):
            values[id(node)] = apply_per_item(node.fn, values[id(node.upstream)])
        elif isinstance(node, MaskNode):
            values[id(node)] = apply_per_item(
                _mask_fn(node.pred), values[id(node.upstream)]
            )
        elif isinstance(node, SegmentNode):
            states, outs = _run_segment(node, values[id(node.upstream)])
            seg_states.append(states)
            values[id(node)] = outs
        elif isinstance(node, ZipNode):
            pair = (values[id(node.left)], values[id(node.right)])
            values[id(node)] = apply_per_item(lambda ab: node.combine(*ab), pair)
        elif isinstance(node, ConcatNode):
            values[id(node)] = _concat_items(
                values[id(node.left)], values[id(node.right)]
            )
        else:  # pragma: no cover
            raise TypeError(f"unknown node {node!r}")
    return values[id(sink)], tuple(seg_states)


# ---------------------------------------------------------------------------
# Chain lowering: spine normal form for the pipeline engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChainSegment:
    """One fused run of dependent cells in the lowered chain."""

    cell_fn: CellFn
    init_state: PyTree
    num_cells: int
    mutable_state: bool
    remat: bool
    # Fused stateless transform applied to each item entering the segment
    # (a spine map pushed into its consumer — Clash-of-the-Lambdas-style
    # push fusion).  Must preserve the flowing item structure.
    pre_fn: Callable[[PyTree], PyTree] | None = None
    # Read-only per-cell leaves (scan xs only — see SegmentNode).
    const_state: PyTree | None = None


@dataclasses.dataclass(frozen=True)
class ChainInjection:
    """One source feeding the chain at a given cell boundary.

    ``cell_index`` 0 injects at the chain entry; interior indices merge
    into the flow via ``combine(flowing, source_item)`` right before that
    cell; ``cell_index == num_cells`` merges after the last cell
    (post-pipeline).  ``combine is None`` only for the primary source.
    ``materialize()`` returns the prepared items (source + fused maps),
    computed once — never replicated per stage.
    """

    materialize: Callable[[], PyTree]
    cell_index: int
    combine: Callable[[PyTree, PyTree], PyTree] | None


@dataclasses.dataclass(frozen=True)
class ChainFeedback:
    """Feedback closure of a lowered chain.

    ``injections[0].materialize()`` yields the ``lag`` init items; item
    ``b >= lag`` is ``emit(out[b - lag])`` — with any tail maps of the
    spine already composed *into* ``emit``, because the emitted item is
    both what re-enters the chain and what is collected.
    """

    lag: int
    emit: Callable[[PyTree], PyTree]


@dataclasses.dataclass(frozen=True)
class ChainProgram:
    """Spine-normal-form program: what the Future engine pipelines.

    ``injections[0]`` is the primary source (combine ``None``); every
    other injection carries the zip combine that merges it in.  The
    flowing item structure is fixed from the entry on (ring buffers are
    shape-static), so interior combines must be structure-preserving.

    With ``feedback`` set, the primary source holds only the first
    ``feedback.lag`` items; the rest of the stream unfolds from its own
    outputs (``finalize`` is always ``None`` then — tail maps fold into
    the emit).
    """

    segments: tuple[ChainSegment, ...]
    injections: tuple[ChainInjection, ...]
    finalize: Callable[[PyTree], PyTree] | None
    num_cells: int
    num_items: int
    feedback: ChainFeedback | None = None


def _pure_feed(node: Node):
    """A stateless branch (source + maps/masks/concats/zips of such):
    returns a ``materialize`` closure, or None if the branch has state."""
    if isinstance(node, SourceNode):
        return lambda: node.items
    if isinstance(node, MapNode):
        inner = _pure_feed(node.upstream)
        if inner is None:
            return None
        return lambda: apply_per_item(node.fn, inner())
    if isinstance(node, MaskNode):
        inner = _pure_feed(node.upstream)
        if inner is None:
            return None
        return lambda: apply_per_item(_mask_fn(node.pred), inner())
    if isinstance(node, ConcatNode):
        lf, rf = _pure_feed(node.left), _pure_feed(node.right)
        if lf is None or rf is None:
            return None
        return lambda: _concat_items(lf(), rf())
    if isinstance(node, ZipNode):
        lf, rf = _pure_feed(node.left), _pure_feed(node.right)
        if lf is None or rf is None:
            return None
        return lambda: apply_per_item(lambda ab: node.combine(*ab), (lf(), rf()))
    return None


def lower_chain(sink: Node) -> ChainProgram:
    """Compile a spine-normal-form graph to a :class:`ChainProgram`.

    Walks the spine from sink to root, fusing maps into their consumers:
    tail maps into ``finalize``, source-side maps into each injection's
    ``materialize``, interior spine maps into the downstream segment's
    ``pre_fn`` (or the downstream zip's combine).  A ``zip`` contributes
    an injection at the current cell boundary; its non-trunk side must be
    stateless.  Raises ``ValueError`` for graphs with no linear-pipeline
    realization (zip of two stateful pipelines) — run those under
    ``LazyEvaluator``, which executes the general DAG.
    """
    num_items = _num_items(sink)

    # Walk sink -> root (downstream to upstream), collecting spine ops in
    # reverse order.  Maps buffer in ``pending`` until the next spine op
    # up the walk reveals their producer: if the producer is the root
    # source they belong to its materialize (per-item prepare, free to
    # change structure); otherwise they fuse into the *downstream*
    # consumer recorded last (segment pre_fn / zip combine / finalize).
    rev_segments: list[ChainSegment] = []
    # (cells_after, combine, materialize), downstream-first.
    rev_injections: list[tuple[int, Callable, Callable]] = []
    finalize: Callable | None = None
    pending: list[Callable] = []  # maps since the last spine op, downstream-first
    consumer: str = "finalize"  # what the next flush attaches to
    cells_after = 0  # cells strictly downstream of the walk position

    def _composed() -> Callable:
        fns = list(pending)  # fns[0] applied last (it is the most downstream)
        g = fns[-1]
        for fn in reversed(fns[:-1]):
            g = _compose(fn, g)
        return g

    def _flush():
        nonlocal finalize, pending
        if not pending:
            return
        fn = _composed()
        if consumer == "finalize":
            # The walk leaves "finalize" after the first spine op, so this
            # flush happens at most once.
            assert finalize is None
            finalize = fn
        elif consumer == "segment":
            seg = rev_segments[-1]
            pre = fn if seg.pre_fn is None else _compose(seg.pre_fn, fn)
            rev_segments[-1] = dataclasses.replace(seg, pre_fn=pre)
        else:  # "zip": wrap the combine's flowing argument
            ca, combine, feed = rev_injections[-1]
            rev_injections[-1] = (
                ca,
                lambda flow, src, _f=fn, _c=combine: _c(_f(flow), src),
                feed,
            )
        pending = []

    node = sink
    while True:
        if isinstance(node, (MapNode, MaskNode)):
            fn = node.fn if isinstance(node, MapNode) else _mask_fn(node.pred)
            pending.append(fn)
            node = node.upstream
        elif isinstance(node, SegmentNode):
            _flush()
            rev_segments.append(
                ChainSegment(
                    cell_fn=node.cell_fn,
                    init_state=node.init_state,
                    num_cells=node.num_cells,
                    mutable_state=node.mutable_state,
                    remat=node.remat,
                    const_state=node.const_state,
                )
            )
            consumer = "segment"
            cells_after += node.num_cells
            node = node.upstream
        elif isinstance(node, ZipNode):
            _flush()
            feed, trunk, combine = _split_zip(node)
            if feed is None:
                raise ValueError(
                    "zip of two stateful pipelines has no linear-pipeline "
                    "form; evaluate this graph with LazyEvaluator instead"
                )
            rev_injections.append((cells_after, combine, feed))
            consumer = "zip"
            node = trunk
        elif isinstance(node, FeedbackNode):
            # Maps between the feedback root and the first spine op apply
            # to *every* entering item — init and fed-back alike — so they
            # fuse downstream (segment pre_fn / zip combine / finalize),
            # never into the init-items materialize.
            _flush()
            emit = node.emit
            if finalize is not None:
                # Tail maps run before the emit: the emitted item is both
                # the fed-back input and the collected output.
                tail, finalize = finalize, None
                emit = lambda x, _t=tail, _e=node.emit: _e(_t(x))
            return _finish_chain(
                rev_segments,
                rev_injections,
                finalize,
                lambda _n=node: _n.init_items,
                num_items,
                feedback=ChainFeedback(lag=node.lag, emit=emit),
            )
        elif isinstance(node, (SourceNode, ConcatNode)):
            feed = _pure_feed(node)
            if feed is None:
                raise ValueError(
                    "the spine's root must be a stateless branch (source + "
                    "maps/concats); a concat of stateful pipelines has no "
                    "linear-pipeline form — use LazyEvaluator"
                )
            if pending:  # maps directly above the root: prepare the feed
                fn = _composed()
                inner = feed
                feed = lambda _f=fn, _i=inner: apply_per_item(_f, _i())
            return _finish_chain(
                rev_segments, rev_injections, finalize, feed, num_items
            )
        else:  # pragma: no cover
            raise TypeError(f"unknown node {node!r}")


def _split_zip(node: ZipNode):
    """Pick the stateless side of a zip as the feed branch.

    Prefers ``right`` as the feed (``a.zip(b, f)`` reads "merge b into
    a"); if only ``left`` is stateless the combine's arguments flip so
    the surviving trunk stays the first argument.
    Returns ``(feed_materialize | None, trunk_node, combine)``.
    """
    right_feed = _pure_feed(node.right)
    if right_feed is not None:
        return right_feed, node.left, node.combine
    left_feed = _pure_feed(node.left)
    if left_feed is not None:
        c = node.combine
        return left_feed, node.right, (lambda flow, src, _c=c: _c(src, flow))
    return None, node, None


def _finish_chain(rev_segments, rev_injections, finalize,
                  primary_feed, num_items,
                  feedback: ChainFeedback | None = None) -> ChainProgram:
    segments = tuple(reversed(rev_segments))
    num_cells = sum(s.num_cells for s in segments)
    injections = [
        ChainInjection(materialize=primary_feed, cell_index=0, combine=None)
    ]
    # rev order = downstream-first; restore spine order (upstream-first) so
    # same-boundary combines fold in program order.
    for cells_after, combine, feed in reversed(rev_injections):
        cell_index = num_cells - cells_after
        if feedback is not None and num_cells > 0 and cell_index >= num_cells:
            raise ValueError(
                "a zip after the last cell of a feedback chain is "
                "ambiguous (the fed-back item would not see the merge); "
                "move the zip before the final segment"
            )
        injections.append(
            ChainInjection(
                materialize=feed, cell_index=cell_index, combine=combine,
            )
        )
    return ChainProgram(
        segments=segments,
        injections=tuple(injections),
        finalize=finalize,
        num_cells=num_cells,
        num_items=num_items,
        feedback=feedback,
    )


# ---------------------------------------------------------------------------
# Multi-segment state unification (for the pipelined executor)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class UnifiedChain:
    """One cell_fn + one stacked state for a multi-segment chain.

    The per-cell state is ``{"seg": i, "pos": k, "parts": (...,)}`` where
    ``parts[i]`` holds segment *i*'s state rows at that segment's cells
    (zeros elsewhere — the padding cost is why single-segment chains take
    the un-wrapped fast path).  ``cell_fn`` dispatches on ``seg`` with
    ``lax.switch``, applying a segment's fused ``pre_fn`` only at its
    first cell, so per-cell compute stays one segment's cell.
    ``split_states(final)`` recovers per-segment final states.

    ``const_state`` mirrors the same padded-parts layout for segments'
    read-only state (``None`` when no segment has any): the unified
    ``cell_fn`` is the canonical 3-arg form, with the const row arriving
    as scan xs — never carried, never written back.
    """

    cell_fn: CellFn
    init_state: PyTree
    num_cells: int
    mutable_state: bool
    remat: bool
    split_states: Callable[[PyTree], tuple[PyTree, ...]]
    const_state: PyTree | None = None


def _check_pre_fn_structure(pre_fn, item) -> None:
    """A fused pre_fn runs under ``lax.cond`` against identity, so it must
    keep the flowing item's pytree structure and leaf shapes/dtypes —
    surface that contract as a clear error, not a cond type mismatch."""
    ref = jax.eval_shape(lambda x: x, item)
    got = jax.eval_shape(pre_fn, item)
    if not structures_match(ref, got):
        raise ValueError(
            "a mid-spine map/mask fused into a segment must preserve the "
            "flowing item structure (the pipeline's ring buffers are "
            f"shape-static), got {_tree_structure(got)} from "
            f"{_tree_structure(ref)}; structure-changing transforms "
            "between segments have no linear-pipeline form — evaluate "
            "this graph with LazyEvaluator instead"
        )


def unify_segments(segments: tuple[ChainSegment, ...]) -> UnifiedChain:
    """Fuse heterogeneous segments into one scannable chain."""
    num_cells = sum(s.num_cells for s in segments)
    offsets = []
    off = 0
    for s in segments:
        offsets.append(off)
        off += s.num_cells

    seg_id = jnp.concatenate(
        [jnp.full((s.num_cells,), i, jnp.int32) for i, s in enumerate(segments)]
    )
    pos = jnp.concatenate(
        [jnp.arange(s.num_cells, dtype=jnp.int32) for s in segments]
    )

    def _pad(leaf, i):
        full = jnp.zeros((num_cells,) + leaf.shape[1:], leaf.dtype)
        return lax.dynamic_update_slice_in_dim(full, leaf, offsets[i], axis=0)

    parts = tuple(
        jax.tree.map(lambda l, _i=i: _pad(l, _i), s.init_state)
        for i, s in enumerate(segments)
    )
    init_state = {"seg": seg_id, "pos": pos, "parts": parts}

    any_const = any(s.const_state is not None for s in segments)
    const_state = None
    if any_const:
        const_state = {
            "parts": tuple(
                None
                if s.const_state is None
                else jax.tree.map(lambda l, _i=i: _pad(l, _i), s.const_state)
                for i, s in enumerate(segments)
            )
        }

    cell_fns = [
        _const_cell(s.cell_fn, s.const_state is not None)
        for s in segments
    ]
    cell_fns = [
        jax.checkpoint(fn) if s.remat else fn
        for fn, s in zip(cell_fns, segments)
    ]

    def branch(i):
        seg = segments[i]

        def run(crow, urow, item):
            it = item
            if seg.pre_fn is not None:
                _check_pre_fn_structure(seg.pre_fn, item)
                it = lax.cond(urow["pos"] == 0, seg.pre_fn, lambda x: x, item)
            crow_i = crow["parts"][i] if any_const else None
            new_si, out = cell_fns[i](crow_i, urow["parts"][i], it)
            if not seg.mutable_state:
                new_si = urow["parts"][i]
            new_parts = urow["parts"][:i] + (new_si,) + urow["parts"][i + 1 :]
            return {**urow, "parts": new_parts}, out

        return run

    branches = [branch(i) for i in range(len(segments))]

    def cell_fn(crow, urow, item):
        return lax.switch(urow["seg"], branches, crow, urow, item)

    def split_states(final_state):
        return tuple(
            jax.tree.map(
                lambda l, _i=i, _s=s: lax.dynamic_slice_in_dim(
                    l, offsets[_i], _s.num_cells, axis=0
                ),
                final_state["parts"][i],
            )
            for i, s in enumerate(segments)
        )

    return UnifiedChain(
        cell_fn=cell_fn,
        init_state=init_state,
        num_cells=num_cells,
        mutable_state=any(s.mutable_state for s in segments),
        # remat is applied per-branch above, never re-wrapped outside.
        remat=False,
        split_states=split_states,
        const_state=const_state,
    )


# ---------------------------------------------------------------------------
# Sequential reference executor (feedback-capable)
# ---------------------------------------------------------------------------


def structures_match(ref, got) -> bool:
    """True when two pytrees agree on structure and leaf shapes/dtypes —
    the shape-static contract every ring-buffered value must satisfy.
    Single comparison site shared by the emit, pre_fn and entry-zip
    validators (Lazy and Future must never diverge on it)."""
    sig = lambda t: [
        (getattr(l, "shape", None), getattr(l, "dtype", None))
        for l in jax.tree.leaves(t)
    ]
    return _tree_structure(ref) == _tree_structure(got) and sig(ref) == sig(got)


def _check_emit_structure(emit, item) -> None:
    """The feedback emit travels the same shape-static ring buffers as
    every inter-cell hand-off, so it must keep the flowing item's pytree
    structure and leaf shapes/dtypes."""
    ref = jax.eval_shape(lambda x: x, item)
    got = jax.eval_shape(emit, item)
    if not structures_match(ref, got):
        raise ValueError(
            "a feedback emit must preserve the flowing item structure "
            "(the emitted item re-enters the chain and is collected); "
            f"got {_tree_structure(got)} from {_tree_structure(ref)}"
        )


def _chain_cell_machinery(chain: "ChainProgram"):
    """(cell_fn, init_state, const_state, mutable, split_states) for a
    lowered chain — the raw fast path for one plain segment, the
    switch-dispatched unified state otherwise.  Shared by both executors
    so the per-cell primitive sequence (hence bit-equality) is identical.
    ``cell_fn`` is always the canonical 3-arg form ``(const, state, item)
    -> (state', item')``; ``const_state`` is None for const-free chains
    (executors still pass it — None threads through scans as an empty
    pytree, so one call shape serves both)."""
    if not chain.segments:
        return None, (), None, False, lambda fs: ()
    if len(chain.segments) == 1 and chain.segments[0].pre_fn is None:
        seg = chain.segments[0]
        cell_fn = _const_cell(seg.cell_fn, seg.const_state is not None)
        if seg.remat:
            cell_fn = jax.checkpoint(cell_fn)
        return (
            cell_fn, seg.init_state, seg.const_state, seg.mutable_state,
            lambda fs: (fs,),
        )
    uni = unify_segments(chain.segments)
    return (
        uni.cell_fn, uni.init_state, uni.const_state, uni.mutable_state,
        uni.split_states,
    )


def run_chain_sequential(chain: "ChainProgram") -> tuple[tuple, PyTree]:
    """Execute a lowered :class:`ChainProgram` item-by-item on one device.

    The Lazy monad over the *lowered* form: one ``lax.scan`` over items,
    cells advanced by inner scans split only at interior injection
    boundaries.  This is the executor that runs feedback chains
    sequentially (``lazy_eval_graph`` cannot — feedback has no node-local
    order): the carry holds a ``lag``-deep FIFO of pending inputs, and
    each emitted item is both collected and pushed onto the FIFO's tail.

    Returns ``(segment_states, out_items)`` like the Future engine.
    """
    n = chain.num_items
    feeds = [inj.materialize() for inj in chain.injections]
    fb = chain.feedback
    cell_fn, init_state, const_state, mutable, split_states = (
        _chain_cell_machinery(chain)
    )

    entry = [
        i for i, inj in enumerate(chain.injections)
        if i > 0 and inj.cell_index == 0
    ]
    interior = [
        i for i, inj in enumerate(chain.injections)
        if 0 < inj.cell_index < chain.num_cells
    ]
    tail = [
        i for i, inj in enumerate(chain.injections)
        if i > 0 and chain.num_cells > 0 and inj.cell_index >= chain.num_cells
    ]
    boundaries = sorted({chain.injections[i].cell_index for i in interior})
    spans = list(
        zip([0] + boundaries, boundaries + [chain.num_cells])
    ) if chain.num_cells else []

    def run_item(states, flow, src_items):
        for i in entry:
            flow = chain.injections[i].combine(flow, src_items[str(i)])
        parts = []
        for a, b in spans:
            for i in interior:
                if chain.injections[i].cell_index == a:
                    flow = chain.injections[i].combine(flow, src_items[str(i)])
            sub = jax.tree.map(lambda l: l[a:b], states)
            sub_const = jax.tree.map(lambda l: l[a:b], const_state)
            flow, new_sub = lax.scan(
                scan_cell(cell_fn, mutable), flow, (sub_const, sub)
            )
            parts.append(new_sub)
        if not parts:
            return states, flow
        if len(parts) == 1:
            return parts[0], flow
        return jax.tree.map(
            lambda *ps: jnp.concatenate(ps, axis=0), *parts
        ), flow

    src_xs = {
        str(i): feeds[i] for i in entry + interior
    }  # every non-primary source has n items

    if fb is not None:
        flow0 = jax.tree.map(lambda x: x[0], feeds[0])
        for i in entry:
            flow0 = chain.injections[i].combine(
                flow0, jax.tree.map(lambda x: x[0], feeds[i])
            )
        _check_emit_structure(fb.emit, flow0)

        def step(carry, xs):
            states, ring = carry
            flow = jax.tree.map(lambda r: r[0], ring)
            new_states, out = run_item(states, flow, xs)
            emitted = fb.emit(out)
            ring = jax.tree.map(
                lambda r, e: jnp.concatenate([r[1:], e[None]], axis=0),
                ring,
                emitted,
            )
            return (new_states, ring), emitted

        (final_states, _), outs = lax.scan(
            step, (init_state, feeds[0]), src_xs, length=n
        )
        return split_states(final_states), outs

    def step(carry, xs):
        new_states, out = run_item(carry, xs["__primary__"], xs)
        return new_states, out

    xs = dict(src_xs)
    xs["__primary__"] = feeds[0]
    final_states, outs = lax.scan(step, init_state, xs, length=n)
    for i in tail:
        outs = apply_per_item(
            lambda ab, _c=chain.injections[i].combine: _c(*ab),
            (outs, feeds[i]),
        )
    if chain.finalize is not None:
        outs = apply_per_item(chain.finalize, outs)
    return split_states(final_states), outs
