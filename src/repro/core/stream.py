"""Stream re-interpreted with a substitutable evaluation monad.

This is the JAX port of the paper's central construct:

    class Cons(hd: A, tl: Future[Stream[A]]) extends Stream[A]

A *bounded* stream program is a chain of dependent cells.  Each cell owns
mutable per-cell state and transforms the item flowing through it::

    cell_fn : (state_s, item) -> (state_s', item')

Items (the paper's stream *elements*; in production, microbatches or
sequence chunks) flow through the cells in order.  The semantics are fixed
and evaluator-independent:

    item b reaches cell s only after item b-1 has left cell s, and after
    item b has left cell s-1.

Two evaluators implement these semantics — the paper's Lazy/Future monad
substitution:

* :class:`LazyEvaluator` — ``lax.scan`` over items and cells on the local
  device.  Sequential, memoized carry: the Lazy monad.
* :class:`FutureEvaluator` — cells are sharded across a mesh axis and items
  are software-pipelined through them with ``lax.ppermute``.  Each cell's
  output is "a future" — an in-flight buffer the next stage forces by
  consuming it one tick later.  The Future monad, TPU-style.

Both produce bit-identical results (tested, including under hypothesis);
only the schedule differs.  This mirrors the paper's claim that the
algorithm text is unchanged when substituting Future for Lazy.

Unbounded streams do not exist on XLA (shape-static); the paper itself
bounds the stream in its Future version ("otherwise the computation will
not stop since it is asynchronous").  We adopt the same concession:
streams are bounded, with masked validity where needed.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any
CellFn = Callable[[PyTree, PyTree], tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# Program
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamProgram:
    """A bounded stream of ``num_cells`` dependent cells.

    Attributes:
      cell_fn: ``(state, item) -> (new_state, out_item)``.  Pure.  Applied
        once per (cell, item) pair.  The cell index, if needed, should be
        carried inside ``state`` (see :func:`indexed_states`).
      init_state: per-cell state, every leaf stacked with leading axis
        ``num_cells``.
      num_cells: chain length (the paper's stream length).
    """

    cell_fn: CellFn
    init_state: PyTree
    num_cells: int
    # False => cells never mutate their state (e.g. the state is layer
    # parameters).  Evaluators then skip the masked state write-back, which
    # would otherwise materialize a full copy of the state per tick.
    mutable_state: bool = True
    # Rematerialize cell_fn on the backward pass (GPipe-style activation
    # checkpointing per (cell, item) pair).
    remat: bool = False

    def __post_init__(self):
        leaves = jax.tree.leaves(self.init_state)
        for leaf in leaves:
            if hasattr(leaf, "shape") and leaf.shape[:1] != (self.num_cells,):
                raise ValueError(
                    f"init_state leaves must have leading axis num_cells="
                    f"{self.num_cells}, got shape {leaf.shape}"
                )


def indexed_states(state: PyTree, num_cells: int) -> PyTree:
    """Attach a cell-index leaf to per-cell state (helper)."""
    return {"index": jnp.arange(num_cells), "state": state}


# ---------------------------------------------------------------------------
# Lazy evaluator — the Lazy monad (sequential, memoized)
# ---------------------------------------------------------------------------


class LazyEvaluator:
    """Sequential evaluation: scan items (outer) through cells (inner).

    Equivalent to the paper's ``Future(value: => A)`` with ``lazy val``
    memoization — every tail is evaluated exactly once, on demand, on the
    calling thread.
    """

    name = "lazy"

    def __call__(self, program: StreamProgram, items: PyTree) -> tuple[PyTree, PyTree]:
        """Run ``items`` (leading axis = stream of M items) through the chain.

        Returns ``(final_states, out_items)`` with ``out_items`` leading
        axis M (item b after all cells).
        """

        cell_fn = (
            jax.checkpoint(program.cell_fn) if program.remat else program.cell_fn
        )

        def item_step(states, item):
            def cell(flowing, state):
                new_state, out = cell_fn(state, flowing)
                if not program.mutable_state:
                    new_state = state
                return out, new_state

            out, new_states = lax.scan(cell, item, states)
            return new_states, out

        return lax.scan(item_step, program.init_state, items)


# ---------------------------------------------------------------------------
# Future evaluator — cells pipelined across a mesh axis
# ---------------------------------------------------------------------------


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


class FutureEvaluator:
    """Pipelined evaluation across ``axis_name`` of ``mesh``.

    ``num_cells`` must be divisible by the axis size D; each device owns a
    contiguous group of ``num_cells // D`` cells (one *stage*).  Item b is
    processed by stage s at tick ``t = b + s``; stage s's output at tick t
    is ``ppermute``\\ d to stage s+1, which forces it (consumes the future)
    at tick t+1.  Steady state keeps all D stages busy; fill/drain bubbles
    cost ``(D-1)/(M+D-1)`` of the ticks — the paper's observation that
    per-cell footprint (chunk size) must dominate the overhead, made exact.

    The schedule is data-oblivious, so ``jax.grad`` through it yields the
    reversed (backward) pipeline automatically — GPipe by autodiff.
    """

    name = "future"

    def __init__(self, mesh: jax.sharding.Mesh, axis_name: str):
        self.mesh = mesh
        self.axis_name = axis_name
        # Partial-manual shard_map: only the pipeline axis is manual; any
        # other mesh axes (data/model) keep automatic GSPMD partitioning,
        # so stages can themselves be FSDP×TP sharded (production mode).
        self._partial = len(mesh.axis_names) > 1

    def __call__(self, program: StreamProgram, items: PyTree) -> tuple[PyTree, PyTree]:
        axis = self.axis_name
        num_devices = self.mesh.shape[axis]
        if program.num_cells % num_devices != 0:
            raise ValueError(
                f"num_cells={program.num_cells} not divisible by axis "
                f"'{axis}' size {num_devices}"
            )
        num_items = jax.tree.leaves(items)[0].shape[0]

        spec_state = jax.tree.map(
            lambda _: jax.sharding.PartitionSpec(axis), program.init_state
        )
        spec_rep = jax.tree.map(lambda _: jax.sharding.PartitionSpec(), items)

        shard_map_kwargs = dict(
            mesh=self.mesh,
            in_specs=(spec_state, spec_rep),
            out_specs=(spec_state, spec_rep),
        )
        if self._partial:
            shard_map_kwargs["axis_names"] = {axis}

        @partial(jax.shard_map, **shard_map_kwargs)
        def pipelined(local_states, items):
            stage = lax.axis_index(axis)
            # The loop carry varies per-device; mark it so (JAX>=0.8 vma).
            def _varying(x):
                return lax.pcast(x, (axis,), to="varying")

            item0 = jax.tree.map(lambda x: _varying(jnp.zeros_like(x[0])), items)
            outs0 = jax.tree.map(lambda x: _varying(jnp.zeros_like(x)), items)

            cell_fn = (
                jax.checkpoint(program.cell_fn)
                if program.remat
                else program.cell_fn
            )

            def stage_fn(states, flowing):
                # One device-stage = Lazy scan over its local cells: the
                # Future monad wraps whole chunks of the chain (the paper's
                # §7 grouping, applied to cells as well as items).
                def cell(fl, st):
                    new_st, out = cell_fn(st, fl)
                    if not program.mutable_state:
                        new_st = st
                    return out, new_st

                out, new_states = lax.scan(cell, flowing, states)
                return new_states, out

            def tick(carry, t):
                local_states, buf, outs = carry
                # Stage 0 injects item t; later stages force the future
                # their predecessor emitted at tick t-1.
                injected = jax.tree.map(
                    lambda x: x[jnp.clip(t, 0, num_items - 1)], items
                )
                inp = _tree_where(stage == 0, injected, buf)
                valid = (t - stage >= 0) & (t - stage < num_items)
                new_states, out = stage_fn(local_states, inp)
                if program.mutable_state:
                    local_states = _tree_where(valid, new_states, local_states)
                # Last stage materializes the result for item t-stage.
                write = valid & (stage == num_devices - 1)
                idx = jnp.clip(t - stage, 0, num_items - 1)
                outs = jax.tree.map(
                    lambda o, v: jnp.where(
                        write, o.at[idx].set(v), o
                    ),
                    outs,
                    out,
                )
                # The future: out is now in flight to stage+1.
                buf = jax.tree.map(
                    lambda x: lax.ppermute(
                        x, axis, [(i, i + 1) for i in range(num_devices - 1)]
                    ),
                    out,
                )
                return (local_states, buf, outs), None

            ticks = jnp.arange(num_items + num_devices - 1)
            (local_states, _, outs), _ = lax.scan(
                tick, (local_states, item0, outs0), ticks
            )
            # Only the last stage holds valid outs; replicate via psum.
            outs = jax.tree.map(
                lambda o: lax.psum(
                    jnp.where(stage == num_devices - 1, o, jnp.zeros_like(o)),
                    axis,
                ),
                outs,
            )
            return local_states, outs

        return pipelined(program.init_state, items)


def evaluate(
    program: StreamProgram,
    items: PyTree,
    evaluator: LazyEvaluator | FutureEvaluator | None = None,
) -> tuple[PyTree, PyTree]:
    """Monad-substitution entry point: same program, pluggable evaluator."""
    evaluator = evaluator or LazyEvaluator()
    return evaluator(program, items)
