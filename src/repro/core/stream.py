"""Stream re-interpreted with a substitutable evaluation monad.

This is the JAX port of the paper's central construct:

    class Cons(hd: A, tl: Future[Stream[A]]) extends Stream[A]

A *bounded* stream program is a chain of dependent cells.  Each cell owns
mutable per-cell state and transforms the item flowing through it::

    cell_fn : (state_s, item) -> (state_s', item')

Items (the paper's stream *elements*; in production, microbatches or
sequence chunks) flow through the cells in order.  The semantics are fixed
and evaluator-independent:

    item b reaches cell s only after item b-1 has left cell s, and after
    item b has left cell s-1.

Two evaluators implement these semantics — the paper's Lazy/Future monad
substitution:

* :class:`LazyEvaluator` — ``lax.scan`` over items and cells on the local
  device.  Sequential, memoized carry: the Lazy monad.
* :class:`FutureEvaluator` — a **schedule-pluggable pipeline engine**.
  Cells are sharded across a mesh axis; a host-built
  :class:`repro.core.schedules.SchedulePlan` (``gpipe``, ``one_f_one_b``
  or ``interleaved``) dictates, per tick, which microbatch each device
  advances and through which of its local cell groups.  The inter-stage
  hand-off is a ring ``ppermute`` routed through
  :func:`repro.core.future.ppermute_future`: the collective is *issued
  before* the tick's ``lax.scan`` over local cells and *forced after*
  it, so the permute is in flight during compute (the future is the
  mechanism, not a metaphor).  Input items are round-robin sharded over
  the stage axis and delivered to stage 0 by a reverse-ring carousel
  (no per-stage replication of all M items, no per-tick dynamic
  gather); outputs accumulate only on the last stage and leave the
  region as a stage-sharded buffer (no ``psum`` replication — the
  caller takes the last stage's shard with one static slice).

Both produce bit-identical results (tested, including under hypothesis);
only the schedule differs.  This mirrors the paper's claim that the
algorithm text is unchanged when substituting Future for Lazy — and,
one level up, that the *schedule* can change without touching either.

All constructs (scan, ppermute, where, dynamic slicing, the barrier in
``force``) are differentiable, so ``jax.grad`` through any schedule
yields the reversed backward pipeline automatically.

Unbounded streams do not exist on XLA (shape-static); the paper itself
bounds the stream in its Future version ("otherwise the computation will
not stop since it is asynchronous").  We adopt the same concession:
streams are bounded, with masked validity where needed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.core.future import ppermute_future
from repro.core.schedules import SchedulePlan, build_plan

PyTree = Any
CellFn = Callable[[PyTree, PyTree], tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# Program
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamProgram:
    """A bounded stream of ``num_cells`` dependent cells.

    Attributes:
      cell_fn: ``(state, item) -> (new_state, out_item)``.  Pure.  Applied
        once per (cell, item) pair.  The cell index, if needed, should be
        carried inside ``state`` (see :func:`indexed_states`).
      init_state: per-cell state, every leaf stacked with leading axis
        ``num_cells``.
      num_cells: chain length (the paper's stream length).
    """

    cell_fn: CellFn
    init_state: PyTree
    num_cells: int
    # False => cells never mutate their state (e.g. the state is layer
    # parameters).  Evaluators then skip the masked state write-back, which
    # would otherwise materialize a full copy of the state per tick.
    mutable_state: bool = True
    # Rematerialize cell_fn on the backward pass (GPipe-style activation
    # checkpointing per (cell, item) pair).
    remat: bool = False

    def __post_init__(self):
        leaves = jax.tree.leaves(self.init_state)
        for leaf in leaves:
            if hasattr(leaf, "shape") and leaf.shape[:1] != (self.num_cells,):
                raise ValueError(
                    f"init_state leaves must have leading axis num_cells="
                    f"{self.num_cells}, got shape {leaf.shape}"
                )


def indexed_states(state: PyTree, num_cells: int) -> PyTree:
    """Attach a cell-index leaf to per-cell state (helper)."""
    return {"index": jnp.arange(num_cells), "state": state}


# ---------------------------------------------------------------------------
# Lazy evaluator — the Lazy monad (sequential, memoized)
# ---------------------------------------------------------------------------


class LazyEvaluator:
    """Sequential evaluation: scan items (outer) through cells (inner).

    Equivalent to the paper's ``Future(value: => A)`` with ``lazy val``
    memoization — every tail is evaluated exactly once, on demand, on the
    calling thread.
    """

    name = "lazy"

    def __call__(self, program: StreamProgram, items: PyTree) -> tuple[PyTree, PyTree]:
        """Run ``items`` (leading axis = stream of M items) through the chain.

        Returns ``(final_states, out_items)`` with ``out_items`` leading
        axis M (item b after all cells).
        """

        cell_fn = (
            jax.checkpoint(program.cell_fn) if program.remat else program.cell_fn
        )

        def item_step(states, item):
            def cell(flowing, state):
                new_state, out = cell_fn(state, flowing)
                if not program.mutable_state:
                    new_state = state
                return out, new_state

            out, new_states = lax.scan(cell, item, states)
            return new_states, out

        return lax.scan(item_step, program.init_state, items)


# ---------------------------------------------------------------------------
# Future evaluator — the schedule-pluggable pipeline engine
# ---------------------------------------------------------------------------


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


class FutureEvaluator:
    """Pipelined evaluation across ``axis_name`` of ``mesh``.

    ``num_cells`` must be divisible by ``D * interleave`` where D is the
    axis size.  With ``interleave == 1`` device d owns one contiguous
    group of cells (one stage); with ``interleave == V > 1`` it owns V
    non-contiguous groups (virtual stages ``v*D + d`` — the interleaved
    schedule's layout, which keeps every hand-off on the same one-hop
    ring because virtual stage p+1 always lives on device (d+1) % D).

    The tick loop executes a :class:`~repro.core.schedules.SchedulePlan`:

    * tick t issues the ring ``ppermute`` of the *previous* tick's
      output first (``ppermute_future``), runs the local cell-group
      ``lax.scan``, then forces the permute anchored on that compute —
      the collective and the scan overlap, and a value produced at tick
      t is consumed at tick t+2 (the plan's ``handoff``);
    * items are round-robin sharded over the axis (device d holds items
      ``d, d+D, ...``) and a one-item carousel register rotates them
      into stage 0 exactly when the plan injects them;
    * only the last device writes the output buffer; it is returned
      stage-sharded and the caller slices the final stage's block — no
      collective touches the outs.

    The schedule is data-oblivious, so ``jax.grad`` through it yields the
    reversed (backward) pipeline automatically — GPipe by autodiff (1F1B
    and interleaved inherit the same property; see schedules.py for what
    ``one_f_one_b`` does and does not change forward-only).
    """

    name = "future"

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        axis_name: str,
        schedule: str = "gpipe",
        interleave: int = 1,
    ):
        self.mesh = mesh
        self.axis_name = axis_name
        self.schedule = schedule
        self.interleave = interleave if schedule == "interleaved" else 1
        if schedule != "interleaved" and interleave != 1:
            raise ValueError(f"{schedule=} requires interleave=1, got {interleave}")
        # Partial-manual shard_map: only the pipeline axis is manual; any
        # other mesh axes (data/model) keep automatic GSPMD partitioning,
        # so stages can themselves be FSDP×TP sharded (production mode).

    def plan_for(self, num_microbatches: int) -> SchedulePlan:
        """The tick plan this evaluator would run for M microbatches."""
        return build_plan(
            self.schedule,
            self.mesh.shape[self.axis_name],
            num_microbatches,
            self.interleave,
        )

    def __call__(self, program: StreamProgram, items: PyTree) -> tuple[PyTree, PyTree]:
        axis = self.axis_name
        num_devices = self.mesh.shape[axis]
        num_virtual = num_devices * self.interleave
        if program.num_cells % num_virtual != 0:
            raise ValueError(
                f"num_cells={program.num_cells} not divisible by axis "
                f"'{axis}' size {num_devices} x interleave {self.interleave}"
            )
        cells_per_group = program.num_cells // num_virtual
        num_items = jax.tree.leaves(items)[0].shape[0]
        plan = self.plan_for(num_items)
        d_, v_, k_ = num_devices, self.interleave, plan.num_slots
        m_ = num_items

        # Device-major cell layout: device d's shard holds its V groups
        # back to back (group v = cells of virtual stage v*D + d).  For
        # V == 1 this is the identity; for V > 1 it is one gather at the
        # region boundary (and its inverse on the way out).
        perm = np.concatenate(
            [
                np.arange(cells_per_group) + (v * d_ + d) * cells_per_group
                for d in range(d_)
                for v in range(v_)
            ]
        )
        inv_perm = np.argsort(perm)
        init_state = program.init_state
        if v_ > 1:
            init_state = jax.tree.map(lambda x: x[perm], init_state)

        # Round-robin item shards: global (D, J, ...) with device d's row
        # holding items d, d+D, ...; zero-padded when D does not divide M.
        feed_len = math.ceil(m_ / d_)

        def _to_feed(x):
            pad = feed_len * d_ - m_
            if pad:
                x = jnp.concatenate(
                    [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]
                )
            return jnp.swapaxes(
                x.reshape((feed_len, d_) + x.shape[1:]), 0, 1
            )

        items_fed = jax.tree.map(_to_feed, items)

        spec_shard = lambda tree: jax.tree.map(
            lambda _: jax.sharding.PartitionSpec(axis), tree
        )

        fwd_ring = [(i, (i + 1) % d_) for i in range(d_)]
        rev_ring = [(i, (i - 1) % d_) for i in range(d_)]

        # Plan tables as device constants; rows are consumed as scan xs
        # so no tick indexing ever lowers to a gather.
        xs = {
            "mb": jnp.asarray(plan.microbatch),
            "grp": jnp.asarray(plan.group),
            "rslot": jnp.asarray(plan.read_slot),
            "cslot": jnp.asarray(plan.recv_slot),
            "coll": jnp.asarray(plan.collect),
            "inj_reload": jnp.asarray(plan.feed_reload),
            "inj_idx": jnp.asarray(plan.feed_idx),
            "inj_adv": jnp.asarray(plan.feed_advance),
        }

        cell_fn = (
            jax.checkpoint(program.cell_fn) if program.remat else program.cell_fn
        )
        mutable = program.mutable_state

        def pipelined(stage_ids, local_states, local_items):
            # Stage index arrives as a stage-sharded input rather than
            # lax.axis_index: the latter lowers to PartitionId, which the
            # 0.4.x SPMD partitioner rejects inside partial-manual regions.
            stage = stage_ids[0]
            local_items = jax.tree.map(lambda x: x[0], local_items)  # (J, ...)
            # The loop carry varies per-device; mark it so (vma JAX).
            def _varying(x):
                return compat.pcast(x, (axis,), to="varying")

            item_shape = jax.tree.map(lambda x: x[0], local_items)
            zero_item = jax.tree.map(
                lambda x: _varying(jnp.zeros_like(x)), item_shape
            )
            buf0 = jax.tree.map(
                lambda x: _varying(jnp.zeros((k_,) + x.shape, x.dtype)),
                item_shape,
            )
            outs0 = jax.tree.map(
                lambda x: _varying(jnp.zeros((m_,) + x.shape, x.dtype)),
                item_shape,
            )
            if v_ > 1:
                local_states = jax.tree.map(
                    lambda x: x.reshape((v_, cells_per_group) + x.shape[1:]),
                    local_states,
                )

            def group_scan(states_g, flowing):
                # One device-group = Lazy scan over its local cells: the
                # Future monad wraps whole chunks of the chain (the
                # paper's §7 grouping, applied to cells as well as items).
                def cell(fl, st):
                    new_st, out = cell_fn(st, fl)
                    if not mutable:
                        new_st = st
                    return out, new_st

                out, new_states = lax.scan(cell, flowing, states_g)
                return new_states, out

            def tick(carry, x):
                states, out_prev, feed, buf, outs = carry
                mb = jnp.take(x["mb"], stage)
                grp = jnp.take(x["grp"], stage)
                rslot = jnp.take(x["rslot"], stage)
                cslot = jnp.take(x["cslot"], stage)
                coll = jnp.take(x["coll"], stage)

                # 1. Issue both collectives *now*; they complete while
                # this tick's cell scan runs (forced below).
                send_fut = ppermute_future(out_prev, axis, fwd_ring)
                feed_cur = _tree_where(
                    x["inj_reload"] > 0,
                    jax.tree.map(
                        lambda it: lax.dynamic_index_in_dim(
                            it, x["inj_idx"], keepdims=False
                        ),
                        local_items,
                    ),
                    feed,
                )
                feed_fut = ppermute_future(feed_cur, axis, rev_ring)

                # 2. Input: a fresh injection (stage 0) or a buffered
                # future the predecessor emitted `handoff` ticks ago.
                slot_val = jax.tree.map(
                    lambda b: lax.dynamic_index_in_dim(
                        b, jnp.clip(rslot, 0, k_ - 1), keepdims=False
                    ),
                    buf,
                )
                inp = _tree_where(rslot < 0, feed_cur, slot_val)

                # 3. Advance mb through this tick's cell group.
                if v_ > 1:
                    states_g = jax.tree.map(
                        lambda s: lax.dynamic_index_in_dim(
                            s, grp, keepdims=False
                        ),
                        states,
                    )
                else:
                    states_g = states
                new_sg, out = group_scan(states_g, inp)
                valid = mb >= 0
                if mutable:
                    new_sg = _tree_where(valid, new_sg, states_g)
                    if v_ > 1:
                        states = jax.tree.map(
                            lambda s, g: lax.dynamic_update_index_in_dim(
                                s, g, grp, 0
                            ),
                            states,
                            new_sg,
                        )
                    else:
                        states = new_sg

                # 4. Last virtual stage: materialize the result locally.
                # Masked row-level dynamic update (not where(o.at[].set))
                # so XLA can update the scan carry in place instead of
                # copying the whole outs buffer every tick.
                write = valid & (coll > 0)
                idx = jnp.clip(mb, 0, m_ - 1)
                outs = jax.tree.map(
                    lambda o, v: lax.dynamic_update_index_in_dim(
                        o,
                        jnp.where(
                            write,
                            v,
                            lax.dynamic_index_in_dim(o, idx, keepdims=False),
                        ),
                        idx,
                        0,
                    ),
                    outs,
                    out,
                )

                # 5. Force the futures, anchored on the compute they
                # overlapped; store the arrival in its planned slot.
                arrived = send_fut.force(anchor=out)
                feed_arr = feed_fut.force(anchor=out)
                slot = jnp.clip(cslot, 0, k_ - 1)
                buf = jax.tree.map(
                    lambda b, a: lax.dynamic_update_index_in_dim(
                        b,
                        jnp.where(
                            cslot >= 0,
                            a,
                            lax.dynamic_index_in_dim(b, slot, keepdims=False),
                        ),
                        slot,
                        0,
                    ),
                    buf,
                    arrived,
                )
                feed = _tree_where(x["inj_adv"] > 0, feed_arr, feed_cur)
                return (states, out, feed, buf, outs), None

            carry0 = (local_states, zero_item, zero_item, buf0, outs0)
            (local_states, _, _, _, outs), _ = lax.scan(tick, carry0, xs)
            if v_ > 1:
                local_states = jax.tree.map(
                    lambda x: x.reshape((v_ * cells_per_group,) + x.shape[2:]),
                    local_states,
                )
            return local_states, outs

        pipelined = compat.shard_map(
            pipelined,
            mesh=self.mesh,
            in_specs=(
                jax.sharding.PartitionSpec(axis),
                spec_shard(init_state),
                spec_shard(items),
            ),
            out_specs=(spec_shard(init_state), spec_shard(items)),
            axis_names={axis},
        )
        final_states, outs = pipelined(
            jnp.arange(d_, dtype=jnp.int32), init_state, items_fed
        )
        if v_ > 1:
            final_states = jax.tree.map(lambda x: x[inv_perm], final_states)
        # outs is stage-sharded (D*M, ...); only the last stage's block is
        # real.  One static slice at the boundary — no psum, no all-reduce.
        outs = jax.tree.map(
            lambda o: lax.slice_in_dim(o, (d_ - 1) * m_, d_ * m_, axis=0),
            outs,
        )
        return final_states, outs


def evaluate(
    program: StreamProgram,
    items: PyTree,
    evaluator: LazyEvaluator | FutureEvaluator | None = None,
) -> tuple[PyTree, PyTree]:
    """Monad-substitution entry point: same program, pluggable evaluator."""
    evaluator = evaluator or LazyEvaluator()
    return evaluator(program, items)
