"""Stream re-interpreted with a substitutable evaluation monad.

This is the JAX port of the paper's central construct:

    class Cons(hd: A, tl: Future[Stream[A]]) extends Stream[A]

**The front door is the combinator algebra** (:mod:`repro.core.graph`)::

    from repro.core import Stream

    Stream.source(items)                 # M items, leading axis = stream
          .map(f)                        # stateless per-item transform
          .through(cell_fn, states)      # chain segment of dependent cells
          .zip(other, combine)           # multi-source item-by-item merge
          .concat(other)                 # sequential composition
          .mask(pred)                    # bounded-stream validity tagging
          .collect(evaluator)            # run -> StreamResult(items, states)

Combinators build a :class:`~repro.core.graph.StreamGraph` IR that both
evaluators execute.  A chain segment's cell owns mutable per-cell state
and transforms the item flowing through it::

    cell_fn : (state_s, item) -> (state_s', item')

The semantics are fixed and evaluator-independent:

    item b reaches cell s only after item b-1 has left cell s, and after
    item b has left cell s-1; item b of ``x.zip(y, f)`` is
    ``f(x[b], y[b])`` — source order, never arrival order.

Two evaluators implement these semantics — the paper's Lazy/Future monad
substitution:

* :class:`LazyEvaluator` — topological composition of ``lax.scan``s over
  the IR on the local device.  Sequential, memoized carry: the Lazy
  monad.  Executes *any* well-formed graph, including zips whose both
  sides carry stateful segments.
* :class:`FutureEvaluator` — a **schedule-pluggable pipeline engine**.
  The graph is lowered (:func:`repro.core.graph.lower_chain`) to a spine
  of fused chain segments plus per-source injection points; cells are
  sharded across a mesh axis and a host-built
  :class:`repro.core.schedules.SchedulePlan` (``gpipe``, ``one_f_one_b``
  or ``interleaved``) dictates, per tick, which microbatch each device
  advances and through which of its local cell groups.  The inter-stage
  hand-off is a ring ``ppermute`` routed through
  :func:`repro.core.future.ppermute_future`: the collective is *issued
  before* the tick's ``lax.scan`` over local cells and *forced after*
  it, so the permute is in flight during compute (the future is the
  mechanism, not a metaphor).  **Every source** — one per ``zip`` branch
  — is round-robin sharded over the stage axis (with a rotation offset
  so its items arrive at its injection device on time) and delivered by
  its own reverse-ring feed carousel at its own virtual stage: a zip of
  two sources pipelines with no per-stage replication of either.
  Outputs accumulate only on the last stage and leave the region as a
  stage-sharded buffer (no ``psum`` replication — the caller takes the
  last stage's shard with one static slice).

Both produce bit-identical results (tested, including under hypothesis);
only the schedule differs.  This mirrors the paper's claim that the
algorithm text is unchanged when substituting Future for Lazy — and,
one level up, that the *schedule* can change without touching either.

All constructs (scan, ppermute, switch, where, dynamic slicing, the
barrier in ``force``) are differentiable, so ``jax.grad`` through any
schedule yields the reversed backward pipeline automatically.

Unbounded streams do not exist on XLA (shape-static); the paper itself
bounds the stream in its Future version ("otherwise the computation will
not stop since it is asynchronous").  We adopt the same concession:
streams are bounded, with ``.mask`` validity where needed.

**Migration note** — :class:`StreamProgram` survives as a thin
deprecated adapter over a one-segment graph::

    evaluate(StreamProgram(cell, states, n), items, ev)   # still works
    Stream.from_program(program, items).collect(ev)       # same thing
    Stream.source(items).through(cell, states).collect(ev)  # the new way

New code should build streams with the algebra; multi-source programs
(``zip``/``concat``) have no ``StreamProgram`` spelling.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.core import graph as G
from repro.core.future import ppermute_future
from repro.core.graph import Stream, StreamResult
from repro.core.schedules import (
    SchedulePlan,
    build_backward_plan,
    build_plan,
    validate_backward,
)

PyTree = Any
CellFn = Callable[[PyTree, PyTree], tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# Program (deprecated adapter)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamProgram:
    """A bounded stream of ``num_cells`` dependent cells.

    .. deprecated::
        The combinator algebra (:class:`repro.core.graph.Stream`) is the
        public front door; ``StreamProgram`` remains as an adapter for a
        one-segment chain (``Stream.from_program``) so existing call
        sites migrate incrementally.

    Attributes:
      cell_fn: ``(state, item) -> (new_state, out_item)``.  Pure.  Applied
        once per (cell, item) pair.  The cell index, if needed, should be
        carried inside ``state`` (see :func:`indexed_states`).
      init_state: per-cell state, every leaf stacked with leading axis
        ``num_cells``.
      num_cells: chain length (the paper's stream length).
    """

    cell_fn: CellFn
    init_state: PyTree
    num_cells: int
    # False => cells never mutate their state (e.g. the state is layer
    # parameters).  Evaluators then skip the masked state write-back, which
    # would otherwise materialize a full copy of the state per tick.
    mutable_state: bool = True
    # Rematerialize cell_fn on the backward pass (GPipe-style activation
    # checkpointing per (cell, item) pair).
    remat: bool = False

    def __post_init__(self):
        leaves = jax.tree.leaves(self.init_state)
        for leaf in leaves:
            if hasattr(leaf, "shape") and leaf.shape[:1] != (self.num_cells,):
                raise ValueError(
                    f"init_state leaves must have leading axis num_cells="
                    f"{self.num_cells}, got shape {leaf.shape}"
                )


def indexed_states(state: PyTree, num_cells: int) -> PyTree:
    """Attach a cell-index leaf to per-cell state (helper)."""
    return {"index": jnp.arange(num_cells), "state": state}


def _check_program(program, items) -> bool:
    """Shared Stream/StreamProgram dispatch + item validation.

    Returns True for the legacy StreamProgram form (items validated),
    False for a Stream (which carries its own sources).
    """
    if isinstance(program, Stream):
        if items is not None:
            raise ValueError(
                "a Stream carries its own sources; do not pass items"
            )
        return False
    if isinstance(program, StreamProgram):
        G.leading_axis_size(items, "items")
        return True
    raise TypeError(
        f"expected Stream or StreamProgram, got {type(program).__name__}"
    )


def _as_chain(program, items) -> tuple[G.ChainProgram, bool]:
    """Normalize (StreamProgram, items) | Stream into a ChainProgram.

    Returns ``(chain, legacy)`` — legacy callers get the single
    segment's states back un-tupled.  Builds the one-segment graph
    directly (``Stream.from_program`` warns on use; the adapter itself
    must not).
    """
    if _check_program(program, items):
        stream = Stream.source(items).through(
            program.cell_fn,
            program.init_state,
            num_cells=program.num_cells,
            mutable_state=program.mutable_state,
            remat=program.remat,
        )
        return stream.lower(), True
    return program.lower(), False


# ---------------------------------------------------------------------------
# Lazy evaluator — the Lazy monad (sequential, memoized)
# ---------------------------------------------------------------------------


class LazyEvaluator:
    """Sequential evaluation: topological lax.scan composition of the IR.

    Equivalent to the paper's ``Future(value: => A)`` with ``lazy val``
    memoization — every tail is evaluated exactly once, on demand, on the
    calling thread.  Runs any well-formed graph, including those the
    pipeline lowering rejects (zips of two stateful pipelines).
    """

    name = "lazy"

    def run_graph(self, stream: Stream) -> StreamResult:
        if any(isinstance(n, G.FeedbackNode) for n in stream.nodes()):
            # Feedback has no node-local order; run the lowered chain
            # sequentially (same per-cell primitive sequence as the
            # Future engine, so bit-equality holds for unfolds too).
            states, outs = G.run_chain_sequential(stream.lower())
            return StreamResult(items=outs, states=states)
        outs, states = G.lazy_eval_graph(stream.node)
        return StreamResult(items=outs, states=states)

    def __call__(self, program, items: PyTree = None) -> tuple[PyTree, PyTree]:
        """Run ``items`` (leading axis = stream of M items) through the chain.

        Returns ``(final_states, out_items)`` with ``out_items`` leading
        axis M (item b after all cells).  ``program`` may be a deprecated
        :class:`StreamProgram` (with ``items``) or a :class:`Stream`
        (whose sources carry the items; final states are a tuple, one per
        segment).
        """
        if not _check_program(program, items):
            result = self.run_graph(program)
            return result.states, result.items

        cell_fn = (
            jax.checkpoint(program.cell_fn) if program.remat else program.cell_fn
        )

        def item_step(states, item):
            def cell(flowing, state):
                new_state, out = cell_fn(state, flowing)
                if not program.mutable_state:
                    new_state = state
                return out, new_state

            out, new_states = lax.scan(cell, item, states)
            return new_states, out

        return lax.scan(item_step, program.init_state, items)


# ---------------------------------------------------------------------------
# Future evaluator — the schedule-pluggable pipeline engine
# ---------------------------------------------------------------------------


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _round_robin_feed(x, num_stages: int, n_items: int, offset: int = 0,
                      flip: bool = False):
    """Shard one leaf's item axis round-robin over the stage axis.

    Returns ``(D, ceil(n/D), ...)``: device ``d``'s local feed shard.
    ``offset`` rotates the layout so item ``m`` reaches the injection
    device after ``m`` reverse-ring advances (the forward carousels);
    ``flip`` mirrors it instead — device ``d`` holds items
    ``j*D + (D-1-d)`` and the carousel advances on the *forward* ring,
    so item ``m`` reaches device ``D-1`` at its m-th consumption (the
    planned backward's cotangent-seed carousel).
    """
    d_ = num_stages
    feed_len = math.ceil(n_items / d_)
    pad = feed_len * d_ - n_items
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    x = x.reshape((feed_len, d_) + x.shape[1:])
    if flip:
        x = x[:, ::-1]
    elif offset:
        x = jnp.roll(x, offset, axis=1)
    return jnp.swapaxes(x, 0, 1)


class FutureEvaluator:
    """Pipelined evaluation across ``axis_name`` of ``mesh``.

    The program (a :class:`Stream` or deprecated :class:`StreamProgram`)
    is lowered to a :class:`~repro.core.graph.ChainProgram` — a spine of
    fused chain segments plus one injection point per source.  The total
    cell count must be divisible by ``D * interleave`` where D is the
    axis size, and every interior injection (``zip``) must fall on a
    virtual-stage boundary.  With ``interleave == 1`` device d owns one
    contiguous group of cells (one stage); with ``interleave == V > 1``
    it owns V non-contiguous groups (virtual stages ``v*D + d`` — the
    interleaved schedule's layout, which keeps every hand-off on the same
    one-hop ring because virtual stage p+1 always lives on device
    (d+1) % D).

    The tick loop executes a :class:`~repro.core.schedules.SchedulePlan`:

    * tick t issues the ring ``ppermute`` of the *previous* tick's
      output first (``ppermute_future``), runs the local cell-group
      ``lax.scan``, then forces the permute anchored on that compute —
      the collective and the scan overlap, and a value produced at tick
      t is consumed at tick t+2 (the plan's ``handoff``);
    * every source is round-robin sharded over the axis with a rotation
      offset matching its injection device, and a per-source one-item
      carousel register rotates its items into that device exactly when
      the plan consumes them — multi-source zips pipeline with no
      per-stage replication of any source;
    * only the last device writes the output buffer; it is returned
      stage-sharded and the caller slices the final stage's block — no
      collective touches the outs.

    The plan tables follow the tick-plan column contract documented in
    :mod:`repro.core.schedules` (the single normative description of
    microbatch/group/slot/feed/stash columns).

    Training backward, pluggable (``backward=``):

    * ``"autodiff"`` (default) — the schedule is data-oblivious, so
      ``jax.grad`` through it yields the reversed (backward) pipeline
      automatically: GPipe by autodiff.  Every schedule then stashes
      all ``V*M`` unit inputs per device.
    * ``"planned"`` — the backward is itself a scheduled computation:
      a ``jax.custom_vjp`` runs the combined plan's B units over the
      same one-hop ring in the reverse direction
      (:meth:`_run_chain_planned`), making ``one_f_one_b`` a real
      F/B-interleaved schedule at the plan level rather than a memory
      model.  Gradients are bitwise-equal to the autodiff path.
    """

    name = "future"

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        axis_name: str,
        schedule: str = "gpipe",
        interleave: int = 1,
        backward: str = "autodiff",
    ):
        self.mesh = mesh
        self.axis_name = axis_name
        self.schedule = schedule
        self.interleave = interleave if schedule == "interleaved" else 1
        if schedule != "interleaved" and interleave != 1:
            raise ValueError(f"{schedule=} requires interleave=1, got {interleave}")
        self.backward = validate_backward(backward)
        # Partial-manual shard_map: only the pipeline axis is manual; any
        # other mesh axes (data/model) keep automatic GSPMD partitioning,
        # so stages can themselves be FSDP×TP sharded (production mode).

    def plan_for(
        self,
        num_microbatches: int,
        inject_positions: tuple[int, ...] = (0,),
        feedback_lag: int | None = None,
    ) -> SchedulePlan:
        """The tick plan this evaluator would run for M microbatches."""
        return build_plan(
            self.schedule,
            self.mesh.shape[self.axis_name],
            num_microbatches,
            self.interleave,
            inject_positions=inject_positions,
            feedback_lag=feedback_lag,
        )

    def run_graph(self, stream: Stream) -> StreamResult:
        chain = stream.lower()
        states, outs = self._run_chain(chain)
        return StreamResult(items=outs, states=states)

    def __call__(self, program, items: PyTree = None) -> tuple[PyTree, PyTree]:
        chain, legacy = _as_chain(program, items)
        states, outs = self._run_chain(chain)
        if legacy:
            return states[0], outs
        return states, outs

    # -- chain execution ---------------------------------------------------

    def _run_chain(self, chain: G.ChainProgram) -> tuple[tuple, PyTree]:
        if self.backward == "planned" and chain.num_cells > 0:
            return self._run_chain_planned(chain)
        axis = self.axis_name
        num_devices = self.mesh.shape[axis]
        num_virtual = num_devices * self.interleave
        m_ = chain.num_items
        fb = chain.feedback

        # Segment-free program: pure data plumbing, no pipeline region.
        if chain.num_cells == 0:
            if fb is not None:
                raise ValueError(
                    "a segment-free feedback chain has nothing to "
                    "pipeline; run it with LazyEvaluator"
                )
            feeds = [inj.materialize() for inj in chain.injections]
            outs = feeds[0]
            for inj, feed in zip(chain.injections[1:], feeds[1:]):
                outs = G.apply_per_item(
                    lambda ab, _c=inj.combine: _c(*ab), (outs, feed)
                )
            if chain.finalize is not None:
                outs = G.apply_per_item(chain.finalize, outs)
            return (), outs

        if chain.num_cells % num_virtual != 0:
            raise ValueError(
                f"num_cells={chain.num_cells} not divisible by axis "
                f"'{axis}' size {num_devices} x interleave {self.interleave}"
            )
        cells_per_group = chain.num_cells // num_virtual

        # Injection layout: every zip must land on a virtual-stage
        # boundary; post-pipeline merges (cell_index == num_cells) are
        # applied outside the region.
        pipelined_inj: list[G.ChainInjection] = []
        tail_inj: list[G.ChainInjection] = []
        positions: list[int] = []
        for inj in chain.injections:
            if inj.cell_index >= chain.num_cells and inj.combine is not None:
                tail_inj.append(inj)
                continue
            if inj.cell_index % cells_per_group != 0:
                raise ValueError(
                    f"zip injection at cell {inj.cell_index} does not fall "
                    f"on a virtual-stage boundary (cells_per_group="
                    f"{cells_per_group}, D={num_devices}, "
                    f"V={self.interleave}); move the zip or change the "
                    f"stage split"
                )
            pipelined_inj.append(inj)
            positions.append(inj.cell_index // cells_per_group)

        plan = self.plan_for(
            m_, tuple(positions), feedback_lag=fb.lag if fb else None
        )
        d_, v_, k_ = num_devices, self.interleave, plan.num_slots
        n_src = len(pipelined_inj)
        entry_src = [s for s in range(n_src) if positions[s] == 0]

        # One fused chain: raw fast path for a single plain segment (the
        # common case, and bit/HLO-identical to the pre-algebra engine);
        # switch-dispatched unified state otherwise.  const_state is the
        # read-only half of the split: stage-sharded like the mutable
        # state, but delivered to the cells as scan xs only — it never
        # enters the tick carry, the idle-tick cond, or a write-back.
        cell_fn, init_state, const_state, mutable, split_states = (
            G._chain_cell_machinery(chain)
        )

        # Device-major cell layout: device d's shard holds its V groups
        # back to back (group v = cells of virtual stage v*D + d).  For
        # V == 1 this is the identity; for V > 1 it is one gather at the
        # region boundary (and its inverse on the way out).
        perm = np.concatenate(
            [
                np.arange(cells_per_group) + (v * d_ + d) * cells_per_group
                for d in range(d_)
                for v in range(v_)
            ]
        )
        inv_perm = np.argsort(perm)
        if v_ > 1:
            init_state = jax.tree.map(lambda x: x[perm], init_state)
            const_state = jax.tree.map(lambda x: x[perm], const_state)

        # Per-source round-robin feed shards: global (D, J, ...) with a
        # rotation offset so source s's item m sits on its injection
        # device exactly when the carousel has advanced m times.  A
        # feedback chain's primary source holds only its `lag` init
        # items, so the feed length is per source.
        sources = [inj.materialize() for inj in pipelined_inj]
        src_items = [
            G.leading_axis_size(src, f"source {s} items")
            for s, src in enumerate(sources)
        ]
        feeds_fed = tuple(
            jax.tree.map(
                lambda x, _o=plan.inject_devices[s], _n=src_items[s]:
                    _round_robin_feed(x, d_, _n, offset=_o),
                sources[s],
            )
            for s in range(n_src)
        )

        combines = [inj.combine for inj in pipelined_inj]
        interior_src = [s for s in range(n_src) if positions[s] != 0]

        def entry_fold(feed_items):
            flow = feed_items[0]
            for s in entry_src[1:]:
                flow = combines[s](flow, feed_items[s])
            return flow

        # Flowing item structure: what the entry zips produce (for a
        # single source, the source's own items).
        flow_shape = jax.eval_shape(
            entry_fold,
            [
                jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), src
                )
                for src in sources
            ],
        )
        if fb is not None:
            # A fed-back item re-enters through the same entry combines
            # as an init item, so entry zips on a feedback chain must be
            # structure-preserving overlays; emit must preserve the
            # flowing structure too (it rides the hand-off ring).
            prim_shape = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                sources[0],
            )
            if not G.structures_match(prim_shape, flow_shape):
                raise ValueError(
                    "entry zips on a feedback chain must preserve the "
                    "primary item structure (the fed-back item re-enters "
                    "through the same combines)"
                )
            G._check_emit_structure(fb.emit, flow_shape)

        spec_shard = lambda tree: jax.tree.map(
            lambda _: jax.sharding.PartitionSpec(axis), tree
        )

        fwd_ring = [(i, (i + 1) % d_) for i in range(d_)]
        rev_ring = [(i, (i - 1) % d_) for i in range(d_)]

        # Plan tables as device constants; rows are consumed as scan xs
        # so no tick indexing ever lowers to a gather.
        xs = {
            "mb": jnp.asarray(plan.microbatch),
            "grp": jnp.asarray(plan.group),
            "rslot": jnp.asarray(plan.read_slot),
            "cslot": jnp.asarray(plan.recv_slot),
            "coll": jnp.asarray(plan.collect),
            "emit": jnp.asarray(plan.emit),
            # (num_ticks, num_sources): transposed so scan slices a
            # per-tick row; the python loop over sources indexes it
            # statically.
            "src_reload": jnp.asarray(plan.src_feed_reload.T),
            "src_idx": jnp.asarray(plan.src_feed_idx.T),
            "src_adv": jnp.asarray(plan.src_feed_advance.T),
            "src_consume": jnp.asarray(plan.src_consume.T),
        }

        def pipelined(stage_ids, local_states, local_consts, local_feeds):
            # Stage index arrives as a stage-sharded input rather than
            # lax.axis_index: the latter lowers to PartitionId, which the
            # 0.4.x SPMD partitioner rejects inside partial-manual regions.
            stage = stage_ids[0]
            local_feeds = [
                jax.tree.map(lambda x: x[0], f) for f in local_feeds
            ]  # each (J, ...)
            # The loop carry varies per-device; mark it so (vma JAX).
            def _varying(x):
                return compat.pcast(x, (axis,), to="varying")

            feed_shapes = [
                jax.tree.map(lambda x: x[0], f) for f in local_feeds
            ]
            feed0 = [
                jax.tree.map(lambda x: _varying(jnp.zeros_like(x)), fs)
                for fs in feed_shapes
            ]
            item_shape = jax.tree.map(
                lambda x: jnp.zeros(x.shape, x.dtype), flow_shape
            )
            zero_item = jax.tree.map(
                lambda x: _varying(jnp.zeros_like(x)), item_shape
            )
            buf0 = jax.tree.map(
                lambda x: _varying(jnp.zeros((k_,) + x.shape, x.dtype)),
                item_shape,
            )
            outs0 = jax.tree.map(
                lambda x: _varying(jnp.zeros((m_,) + x.shape, x.dtype)),
                item_shape,
            )
            if v_ > 1:
                local_states = jax.tree.map(
                    lambda x: x.reshape((v_, cells_per_group) + x.shape[1:]),
                    local_states,
                )
                local_consts = jax.tree.map(
                    lambda x: x.reshape((v_, cells_per_group) + x.shape[1:]),
                    local_consts,
                )

            def group_scan(const_g, states_g, flowing):
                # One device-group = Lazy scan over its local cells: the
                # Future monad wraps whole chunks of the chain (the
                # paper's §7 grouping, applied to cells as well as items).
                # The const rows ride the xs side only: read per cell,
                # never part of the carry or the ys write-back.
                # G.scan_cell is the shared scan body — the per-cell
                # primitive sequence must match the Lazy executors'.
                out, new_states = lax.scan(
                    G.scan_cell(cell_fn, mutable), flowing,
                    (const_g, states_g),
                )
                return new_states, out

            def tick(carry, x):
                states, out_prev, feeds, buf, outs = carry
                mb = jnp.take(x["mb"], stage)
                grp = jnp.take(x["grp"], stage)
                rslot = jnp.take(x["rslot"], stage)
                cslot = jnp.take(x["cslot"], stage)
                coll = jnp.take(x["coll"], stage)

                # 1. Issue all collectives *now*; they complete while
                # this tick's cell scan runs (forced below).
                send_fut = ppermute_future(out_prev, axis, fwd_ring)
                feed_curs = []
                feed_futs = []
                for s in range(n_src):
                    fc = _tree_where(
                        x["src_reload"][s] > 0,
                        jax.tree.map(
                            lambda it: lax.dynamic_index_in_dim(
                                it, x["src_idx"][s], keepdims=False
                            ),
                            local_feeds[s],
                        ),
                        feeds[s],
                    )
                    feed_curs.append(fc)
                    feed_futs.append(ppermute_future(fc, axis, rev_ring))

                # 2. Input: a fresh injection (the entry zips' fold over
                # their feed registers), a buffered future the
                # predecessor emitted `handoff` ticks ago — which under
                # feedback is also how item b-lag's emitted output
                # re-enters at position 0 — or, at an injection device,
                # that value merged with the consuming zip's register.
                slot_val = jax.tree.map(
                    lambda b: lax.dynamic_index_in_dim(
                        b, jnp.clip(rslot, 0, k_ - 1), keepdims=False
                    ),
                    buf,
                )
                if fb is None:
                    inp = _tree_where(rslot < 0, entry_fold(feed_curs), slot_val)
                else:
                    # Entry zips gate on their consume column so they
                    # overlay fed-back entries (rslot >= 0) as well as
                    # fresh init items — the carousel admitting new
                    # requests into retired slots mid-flight.
                    inp = _tree_where(rslot < 0, feed_curs[0], slot_val)
                    for s in entry_src[1:]:
                        merged = combines[s](inp, feed_curs[s])
                        apply_s = (x["src_consume"][s] > 0) & (
                            stage == plan.inject_devices[s]
                        )
                        inp = _tree_where(apply_s, merged, inp)
                for s in interior_src:
                    merged = combines[s](inp, feed_curs[s])
                    apply_s = (x["src_consume"][s] > 0) & (
                        stage == plan.inject_devices[s]
                    )
                    inp = _tree_where(apply_s, merged, inp)

                # 3. Advance mb through this tick's cell group.
                if v_ > 1:
                    states_g = jax.tree.map(
                        lambda s: lax.dynamic_index_in_dim(
                            s, grp, keepdims=False
                        ),
                        states,
                    )
                    const_g = jax.tree.map(
                        lambda s: lax.dynamic_index_in_dim(
                            s, grp, keepdims=False
                        ),
                        local_consts,
                    )
                else:
                    states_g = states
                    const_g = local_consts
                valid = mb >= 0
                if mutable:
                    # Idle ticks (fill/drain) skip the cell scan *and*
                    # the state write-back entirely: a whole-state
                    # where(valid, new, old) would copy every cache
                    # byte per tick — the dominant cost of a serving
                    # chain whose state is the KV cache.  Invalid-tick
                    # outputs are never collected, stored, or read, so
                    # passing the input through is unobservable.  The
                    # const rows are a closure capture of the taken
                    # branch, not a cond output — read-only state is
                    # structurally exempt from the write-back.
                    new_sg, out = lax.cond(
                        valid,
                        lambda args: group_scan(const_g, *args),
                        lambda args: args,
                        (states_g, inp),
                    )
                else:
                    new_sg, out = group_scan(const_g, states_g, inp)
                if fb is not None:
                    # Final virtual stage: the emitted item is both the
                    # collected output and — one ring hop later — the
                    # entry input of item mb + lag.  The plan's emit
                    # column (last-stage-only by construction) keys the
                    # sole region containing the LM head: every other
                    # device's tick body never takes this branch, and
                    # the HLO keeps the head matmul conditional-guarded
                    # (asserted in the serving tests).
                    emit_here = jnp.take(x["emit"], stage)
                    out = lax.cond(emit_here > 0, fb.emit, lambda o: o, out)
                if mutable:
                    if v_ > 1:
                        states = jax.tree.map(
                            lambda s, g: lax.dynamic_update_index_in_dim(
                                s, g, grp, 0
                            ),
                            states,
                            new_sg,
                        )
                    else:
                        states = new_sg

                # 4. Last virtual stage: materialize the result locally.
                # Masked row-level dynamic update (not where(o.at[].set))
                # so XLA can update the scan carry in place instead of
                # copying the whole outs buffer every tick.
                write = valid & (coll > 0)
                idx = jnp.clip(mb, 0, m_ - 1)
                outs = jax.tree.map(
                    lambda o, v: lax.dynamic_update_index_in_dim(
                        o,
                        jnp.where(
                            write,
                            v,
                            lax.dynamic_index_in_dim(o, idx, keepdims=False),
                        ),
                        idx,
                        0,
                    ),
                    outs,
                    out,
                )

                # 5. Force the futures, anchored on the compute they
                # overlapped; store the arrival in its planned slot.
                arrived = send_fut.force(anchor=out)
                slot = jnp.clip(cslot, 0, k_ - 1)
                buf = jax.tree.map(
                    lambda b, a: lax.dynamic_update_index_in_dim(
                        b,
                        jnp.where(
                            cslot >= 0,
                            a,
                            lax.dynamic_index_in_dim(b, slot, keepdims=False),
                        ),
                        slot,
                        0,
                    ),
                    buf,
                    arrived,
                )
                new_feeds = tuple(
                    _tree_where(
                        x["src_adv"][s] > 0,
                        feed_futs[s].force(anchor=out),
                        feed_curs[s],
                    )
                    for s in range(n_src)
                )
                return (states, out, new_feeds, buf, outs), None

            carry0 = (local_states, zero_item, tuple(feed0), buf0, outs0)
            (local_states, _, _, _, outs), _ = lax.scan(tick, carry0, xs)
            if v_ > 1:
                local_states = jax.tree.map(
                    lambda x: x.reshape((v_ * cells_per_group,) + x.shape[2:]),
                    local_states,
                )
            return local_states, outs

        pipelined = compat.shard_map(
            pipelined,
            mesh=self.mesh,
            in_specs=(
                jax.sharding.PartitionSpec(axis),
                spec_shard(init_state),
                spec_shard(const_state),
                tuple(spec_shard(f) for f in feeds_fed),
            ),
            out_specs=(spec_shard(init_state), spec_shard(flow_shape)),
            axis_names={axis},
        )
        final_states, outs = pipelined(
            jnp.arange(d_, dtype=jnp.int32), init_state, const_state, feeds_fed
        )
        if v_ > 1:
            final_states = jax.tree.map(lambda x: x[inv_perm], final_states)
        # outs is stage-sharded (D*M, ...); only the last stage's block is
        # real.  One static slice at the boundary — no psum, no all-reduce.
        outs = jax.tree.map(
            lambda o: lax.slice_in_dim(o, (d_ - 1) * m_, d_ * m_, axis=0),
            outs,
        )
        # Post-pipeline merges (zips past the last cell) and fused tail
        # maps apply per item outside the region.
        for inj in tail_inj:
            outs = G.apply_per_item(
                lambda ab, _c=inj.combine: _c(*ab), (outs, inj.materialize())
            )
        if chain.finalize is not None:
            outs = G.apply_per_item(chain.finalize, outs)
        return split_states(final_states), outs

    # -- planned backward (true 1F1B custom-VJP) ---------------------------

    def _run_chain_planned(self, chain: G.ChainProgram) -> tuple[tuple, PyTree]:
        """Execute the chain with the backward pass as scheduled B units.

        The combined plan (:func:`repro.core.schedules.build_combined_plan`)
        is the schedule artifact; this method realizes it under XLA's
        two-phase autodiff protocol with ``jax.custom_vjp``:

        * **fwd** runs the plan's F units (the ordinary forward tick
          scan) and additionally stashes every unit's input activation
          into per-device stash buffers (slot ``group * M + m`` — the
          phase-split coloring; see :class:`~repro.core.schedules.
          CombinedPlan` for why the boundary forces all ``V*M`` live).
        * **bwd** replays the plan's B units in combined-plan order
          (:func:`~repro.core.schedules.build_backward_plan` — the
          mirrored tables): cotangent seeds ``d_out[m]`` ride a flipped
          feed carousel into device D-1, each B unit re-linearizes its
          cell group at the stashed input (``jax.vjp`` — group-level
          rematerialization, so ``remat`` is moot here) and the produced
          input-cotangent rides :func:`~repro.core.future.
          ppermute_future` one hop down the *reverse* ring, overlapping
          the next unit's transpose exactly as the forward overlaps its
          sends.  Entry units emit the source-item gradients on device 0.

        Weight-gradient contributions are staged per (group, m) and
        reduced in reverse forward-tick order (m descending per group) —
        the order ``jax.grad`` of the forward plan accumulates in — so
        planned gradients are *bitwise* equal to the autodiff path
        (tested across the schedule zoo).  The staging buffer is M× the
        stage weight-grad footprint; the ZB-H1 W-unit split (plan
        groundwork shipped) is the path to folding it away.

        Constraints (clear errors otherwise): single-source chains,
        immutable cell state (1F1B's B-unit order ``m = 0..M-1`` is
        only sound when cells never mutate state across items — a
        mutable chain's transpose needs ``m`` *descending*), floating
        point items, no feedback.
        """
        axis = self.axis_name
        d_ = self.mesh.shape[axis]
        v_ = self.interleave
        num_virtual = d_ * v_
        m_ = chain.num_items

        if chain.feedback is not None:
            raise ValueError(
                "backward='planned' does not support feedback chains "
                "(decode loops do not train); use backward='autodiff'"
            )
        if len(chain.injections) != 1:
            raise ValueError(
                "backward='planned' supports single-source chains only "
                "(the training shape: one stream of microbatches); use "
                "backward='autodiff' for zip/multi-source programs"
            )
        if chain.num_cells % num_virtual != 0:
            raise ValueError(
                f"num_cells={chain.num_cells} not divisible by axis "
                f"'{axis}' size {d_} x interleave {v_}"
            )
        cells_per_group = chain.num_cells // num_virtual

        cell_fn, init_state, const_state, mutable, split_states = (
            G._chain_cell_machinery(chain)
        )
        if mutable:
            raise ValueError(
                "backward='planned' requires immutable cell state "
                "(mutable_state=False): the 1F1B backward runs items in "
                "ascending order, which is only a valid transpose when "
                "cells do not mutate state across items; use "
                "backward='autodiff'"
            )
        if const_state is not None:
            raise ValueError(
                "backward='planned' does not support const_state segments "
                "(const leaves are excluded from differentiation by "
                "construction); put read-only differentiable state in an "
                "ordinary mutable_state=False segment, or use "
                "backward='autodiff'"
            )
        cell_fn = lambda st, it, _f=cell_fn: _f(None, st, it)

        src = chain.injections[0].materialize()
        for leaf in jax.tree.leaves(src):
            if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
                raise ValueError(
                    "backward='planned' requires floating-point source "
                    "items (cotangents ride the same ring buffers)"
                )
        G.leading_axis_size(src, "items")

        # Differentiate only the inexact state leaves: the unified
        # multi-segment machinery threads integer bookkeeping (cell /
        # segment indices) through the state, whose cotangents are
        # symbolic float0 — they never ride the ring.
        state_leaves, state_def = jax.tree.flatten(init_state)
        diff_ids = tuple(
            i
            for i, leaf in enumerate(state_leaves)
            if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)
        )

        plan = self.plan_for(m_)
        bplan = build_backward_plan(
            self.schedule, d_, m_, v_, plan.handoff
        )
        k_, kb_ = plan.num_slots, bplan.num_slots
        n_stash = v_ * m_

        perm = np.concatenate(
            [
                np.arange(cells_per_group) + (v * d_ + d) * cells_per_group
                for d in range(d_)
                for v in range(v_)
            ]
        )
        inv_perm = np.argsort(perm)

        item_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), src
        )
        spec_shard = lambda tree: jax.tree.map(
            lambda _: jax.sharding.PartitionSpec(axis), tree
        )
        fwd_ring = [(i, (i + 1) % d_) for i in range(d_)]
        rev_ring = [(i, (i - 1) % d_) for i in range(d_)]

        def _plan_xs(p: SchedulePlan):
            return {
                "mb": jnp.asarray(p.microbatch),
                "grp": jnp.asarray(p.group),
                "rslot": jnp.asarray(p.read_slot),
                "cslot": jnp.asarray(p.recv_slot),
                "coll": jnp.asarray(p.collect),
                "reload": jnp.asarray(p.feed_reload),
                "idx": jnp.asarray(p.feed_idx),
                "adv": jnp.asarray(p.feed_advance),
            }

        xs_f, xs_b = _plan_xs(plan), _plan_xs(bplan)

        def _varying(x):
            return compat.pcast(x, (axis,), to="varying")

        def _zeros(shape_prefix, struct):
            return jax.tree.map(
                lambda s: _varying(jnp.zeros(shape_prefix + s.shape, s.dtype)),
                struct,
            )

        def _row_update(buf, row, idx, write):
            """Masked row write that XLA can do in place (see the outs
            write in the forward engine)."""
            return jax.tree.map(
                lambda b, v: lax.dynamic_update_index_in_dim(
                    b,
                    jnp.where(
                        write,
                        v,
                        lax.dynamic_index_in_dim(b, idx, keepdims=False),
                    ),
                    idx,
                    0,
                ),
                buf,
                row,
            )

        def group_apply(states_g, flowing):
            # Same per-cell primitive sequence as the forward engine's
            # group_scan (bit-equality of outputs and of their vjp).
            def cell(fl, st):
                _st, out = cell_fn(st, fl)
                return out, None

            out, _ = lax.scan(cell, flowing, states_g)
            return out

        def _state_groups(local_states):
            # (V, cells_per_group, ...) local view; V == 1 is group 0.
            return jax.tree.map(
                lambda x: x.reshape((v_, cells_per_group) + x.shape[1:]),
                local_states,
            )

        # -- fwd phase: the forward plan's F units (+ activation stash) ----
        def _make_forward(with_stash: bool):
            """The forward tick scan.  The stash buffer (the planned
            backward's residuals) threads through the scan carry only
            when a VJP will consume it: the primal-only path (forward
            evaluation without jax.grad) must not pay a per-tick
            whole-buffer stash write — the same masked-carry copy cost
            the serving engine's cond-gating exists to avoid."""

            def forward_region(stage_ids, local_states, local_feed):
                stage = stage_ids[0]
                local_feed = jax.tree.map(lambda x: x[0], local_feed)
                states_v = _state_groups(local_states)
                carry0 = (
                    _zeros((), item_struct),      # out_prev
                    _zeros((), jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                        local_feed,
                    )),                            # feed register
                    _zeros((k_,), item_struct),    # in-flight hand-offs
                    _zeros((m_,), item_struct),    # outs
                )
                if with_stash:
                    carry0 += (_zeros((n_stash,), item_struct),)

                def tick(carry, x):
                    out_prev, feed_reg, buf, outs = carry[:4]
                    mb = jnp.take(x["mb"], stage)
                    grp = jnp.take(x["grp"], stage)
                    rslot = jnp.take(x["rslot"], stage)
                    cslot = jnp.take(x["cslot"], stage)
                    coll = jnp.take(x["coll"], stage)

                    send_fut = ppermute_future(out_prev, axis, fwd_ring)
                    fc = _tree_where(
                        x["reload"] > 0,
                        jax.tree.map(
                            lambda it: lax.dynamic_index_in_dim(
                                it, x["idx"], keepdims=False
                            ),
                            local_feed,
                        ),
                        feed_reg,
                    )
                    feed_fut = ppermute_future(fc, axis, rev_ring)

                    slot_val = jax.tree.map(
                        lambda b: lax.dynamic_index_in_dim(
                            b, jnp.clip(rslot, 0, k_ - 1), keepdims=False
                        ),
                        buf,
                    )
                    inp = _tree_where(rslot < 0, fc, slot_val)
                    states_g = jax.tree.map(
                        lambda s: lax.dynamic_index_in_dim(
                            s, grp, keepdims=False
                        ),
                        states_v,
                    )
                    out = group_apply(states_g, inp)

                    valid = mb >= 0
                    outs = _row_update(
                        outs, out, jnp.clip(mb, 0, m_ - 1), valid & (coll > 0)
                    )
                    if with_stash:
                        sslot = jnp.clip(grp * m_ + mb, 0, n_stash - 1)
                        stash = _row_update(carry[4], inp, sslot, valid)

                    arrived = send_fut.force(anchor=out)
                    buf = _row_update(
                        buf, arrived, jnp.clip(cslot, 0, k_ - 1), cslot >= 0
                    )
                    feed_reg = _tree_where(
                        x["adv"] > 0, feed_fut.force(anchor=out), fc
                    )
                    carry_out = (out, feed_reg, buf, outs)
                    if with_stash:
                        carry_out += (stash,)
                    return carry_out, None

                final, _ = lax.scan(tick, carry0, xs_f)
                outs = final[3]
                if with_stash:
                    return outs, final[4]
                return outs

            out_specs = (
                (spec_shard(item_struct), spec_shard(item_struct))
                if with_stash
                else spec_shard(item_struct)
            )
            region = compat.shard_map(
                forward_region,
                mesh=self.mesh,
                in_specs=(
                    jax.sharding.PartitionSpec(axis),
                    spec_shard(init_state),
                    spec_shard(item_struct),
                ),
                out_specs=out_specs,
                axis_names={axis},
            )

            def forward(state0, src_items):
                state_p = (
                    jax.tree.map(lambda x: x[perm], state0)
                    if v_ > 1
                    else state0
                )
                feed = jax.tree.map(
                    lambda x: _round_robin_feed(x, d_, m_), src_items
                )
                res = region(jnp.arange(d_, dtype=jnp.int32), state_p, feed)
                outs, stash = res if with_stash else (res, None)
                outs = jax.tree.map(
                    lambda o: lax.slice_in_dim(
                        o, (d_ - 1) * m_, d_ * m_, axis=0
                    ),
                    outs,
                )
                return outs, stash

            return forward

        _forward_primal = _make_forward(False)
        _forward = _make_forward(True)

        # -- bwd phase: the combined plan's B units over the reverse ring --
        def backward_region(stage_ids, local_states, local_stash,
                            local_dfeed, local_dfinal_diff):
            stage = stage_ids[0]
            local_dfeed = jax.tree.map(lambda x: x[0], local_dfeed)
            states_v = _state_groups(local_states)
            states_v_leaves = jax.tree.leaves(states_v)
            group_diff_struct = tuple(
                jax.ShapeDtypeStruct(
                    states_v_leaves[i].shape[1:], states_v_leaves[i].dtype
                )
                for i in diff_ids
            )
            zero_item = _zeros((), item_struct)
            carry0 = (
                zero_item,                          # cotangent being sent
                _zeros((), jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                    local_dfeed,
                )),                                  # d_out seed register
                _zeros((kb_,), item_struct),         # in-flight cotangents
                _zeros((n_stash,), group_diff_struct),  # staged dW (grp, m)
                _zeros((m_,), item_struct),          # d_items (device 0)
            )

            def tick(carry, x):
                dflow_prev, dfeed_reg, dbuf, staging, ditems = carry
                mb = jnp.take(x["mb"], stage)
                grp = jnp.take(x["grp"], stage)
                rslot = jnp.take(x["rslot"], stage)
                cslot = jnp.take(x["cslot"], stage)
                coll = jnp.take(x["coll"], stage)

                send_fut = ppermute_future(dflow_prev, axis, rev_ring)
                fc = _tree_where(
                    x["reload"] > 0,
                    jax.tree.map(
                        lambda it: lax.dynamic_index_in_dim(
                            it, x["idx"], keepdims=False
                        ),
                        local_dfeed,
                    ),
                    dfeed_reg,
                )
                feed_fut = ppermute_future(fc, axis, fwd_ring)

                slot_val = jax.tree.map(
                    lambda b: lax.dynamic_index_in_dim(
                        b, jnp.clip(rslot, 0, kb_ - 1), keepdims=False
                    ),
                    dbuf,
                )
                g = _tree_where(rslot < 0, fc, slot_val)
                valid = mb >= 0
                sslot = jnp.clip(grp * m_ + mb, 0, n_stash - 1)
                xin = jax.tree.map(
                    lambda s: lax.dynamic_index_in_dim(
                        s, sslot, keepdims=False
                    ),
                    local_stash,
                )
                states_g = jax.tree.map(
                    lambda s: lax.dynamic_index_in_dim(s, grp, keepdims=False),
                    states_v,
                )
                sg_leaves = jax.tree.leaves(states_g)
                sg_def = jax.tree.structure(states_g)
                diff_vals = tuple(sg_leaves[i] for i in diff_ids)

                def apply_diff(diff_vals_, x_):
                    full = list(sg_leaves)
                    for i, val in zip(diff_ids, diff_vals_):
                        full[i] = val
                    return group_apply(jax.tree.unflatten(sg_def, full), x_)

                def unit(args):
                    dv_, x_, g_ = args
                    _out, vjp_fn = jax.vjp(apply_diff, dv_, x_)
                    return vjp_fn(g_)

                def idle(args):
                    dv_, x_, _g = args
                    return (
                        tuple(jnp.zeros_like(v) for v in dv_),
                        jax.tree.map(jnp.zeros_like, x_),
                    )

                dsg, dx = lax.cond(valid, unit, idle, (diff_vals, xin, g))
                staging = _row_update(staging, dsg, sslot, valid)
                ditems = _row_update(
                    ditems, dx, jnp.clip(mb, 0, m_ - 1), valid & (coll > 0)
                )

                arrived = send_fut.force(anchor=dx)
                dbuf = _row_update(
                    dbuf, arrived, jnp.clip(cslot, 0, kb_ - 1), cslot >= 0
                )
                dfeed_reg = _tree_where(
                    x["adv"] > 0, feed_fut.force(anchor=dx), fc
                )
                return (dx, dfeed_reg, dbuf, staging, ditems), None

            (_, _, _, staging, ditems), _ = lax.scan(tick, carry0, xs_b)

            # Weight-grad reduction in the order jax.grad of the forward
            # plan accumulates: per group, microbatch M-1 down to 0,
            # seeded with the final-states cotangent (bitwise parity).
            staging_v = jax.tree.map(
                lambda s: s.reshape((v_, m_) + s.shape[1:]), staging
            )
            dfinal_v = tuple(
                x.reshape((v_, cells_per_group) + x.shape[1:])
                for x in local_dfinal_diff
            )

            def reduce_step(acc, i):
                acc = jax.tree.map(
                    lambda a, s: a
                    + lax.dynamic_index_in_dim(
                        s, m_ - 1 - i, axis=1, keepdims=False
                    ),
                    acc,
                    staging_v,
                )
                return acc, None

            dstates_v, _ = lax.scan(
                reduce_step, dfinal_v, jnp.arange(m_, dtype=jnp.int32)
            )
            dstates_diff = jax.tree.map(
                lambda x: x.reshape((v_ * cells_per_group,) + x.shape[2:]),
                dstates_v,
            )
            return dstates_diff, ditems

        diff_struct = tuple(
            jax.ShapeDtypeStruct(state_leaves[i].shape, state_leaves[i].dtype)
            for i in diff_ids
        )
        backward_region = compat.shard_map(
            backward_region,
            mesh=self.mesh,
            in_specs=(
                jax.sharding.PartitionSpec(axis),
                spec_shard(init_state),
                spec_shard(item_struct),
                spec_shard(item_struct),
                spec_shard(diff_struct),
            ),
            out_specs=(spec_shard(diff_struct), spec_shard(item_struct)),
            axis_names={axis},
        )

        def _backward(state0, stash, d_final_diff, d_outs):
            state_p = (
                jax.tree.map(lambda x: x[perm], state0) if v_ > 1 else state0
            )
            dfinal_p = (
                tuple(x[perm] for x in d_final_diff)
                if v_ > 1
                else tuple(d_final_diff)
            )
            dfeed = jax.tree.map(
                lambda x: _round_robin_feed(x, d_, m_, flip=True), d_outs
            )
            dstates_diff, ditems = backward_region(
                jnp.arange(d_, dtype=jnp.int32), state_p, stash, dfeed,
                dfinal_p,
            )
            if v_ > 1:
                dstates_diff = tuple(x[inv_perm] for x in dstates_diff)
            ditems = jax.tree.map(
                lambda o: lax.slice_in_dim(o, 0, m_, axis=0), ditems
            )
            # Reassemble the full state cotangent: integer bookkeeping
            # leaves get symbolic float0 zeros (the custom_vjp contract).
            out_leaves: list = []
            it = iter(dstates_diff)
            for i, leaf in enumerate(state_leaves):
                if i in diff_ids:
                    out_leaves.append(next(it))
                else:
                    out_leaves.append(
                        np.zeros(np.shape(leaf), jax.dtypes.float0)
                    )
            return jax.tree.unflatten(state_def, out_leaves), ditems

        @jax.custom_vjp
        def run(state0, src_items):
            # Primal-only (no differentiation): the stash-free forward.
            outs, _ = _forward_primal(state0, src_items)
            return state0, outs

        def run_fwd(state0, src_items):
            outs, stash = _forward(state0, src_items)
            return (state0, outs), (state0, stash)

        def run_bwd(res, cot):
            state0, stash = res
            d_final, d_outs = cot
            d_final_diff = tuple(
                leaf
                for i, leaf in enumerate(jax.tree.leaves(d_final))
                if i in diff_ids
            )
            return _backward(state0, stash, d_final_diff, d_outs)

        run.defvjp(run_fwd, run_bwd)
        final_states, outs = run(init_state, src)
        if chain.finalize is not None:
            outs = G.apply_per_item(chain.finalize, outs)
        return split_states(final_states), outs


def evaluate(
    program,
    items: PyTree = None,
    evaluator: LazyEvaluator | FutureEvaluator | None = None,
) -> tuple[PyTree, PyTree]:
    """Monad-substitution entry point: same program, pluggable evaluator.

    ``program`` is a :class:`Stream` (preferred; carries its own sources)
    or a deprecated :class:`StreamProgram` with ``items``.
    """
    evaluator = evaluator or LazyEvaluator()
    return evaluator(program, items)
