"""Layer-pipeline parallelism as a Stream-with-Future program.

A transformer's layer stack *is* a stream: cell = group of layers, item =
microbatch of activations.  Running it under :class:`FutureEvaluator`
pipelines microbatches across a mesh axis — the paper's technique as a
first-class distribution feature (``--pipeline.stages``), intended for the
slow inter-pod links of the production mesh.

The forward schedule is pluggable (:mod:`repro.core.schedules`):

* ``gpipe`` — fill/drain, bubble ``h(S-1)/(M + h(S-1))``;
* ``one_f_one_b`` — 1F1B: under ``backward="planned"`` the combined
  plan interleaves F and B units and bounds the stash at ``min(S, M)``
  microbatches instead of ``M``;
* ``interleaved`` — each device owns ``interleave`` non-contiguous layer
  groups, bubble ``h(S-1)/(V·M + h(S-1))``.

The backward is pluggable too (``PipelineConfig.backward``).  Every
construct used (scan, ring ppermute futures, where, dynamic slicing) is
differentiable, so with ``"autodiff"`` ``jax.grad`` through
:func:`pipeline_apply` yields the reversed backward pipeline
automatically, with per-(cell, item) rematerialization when
``remat=True``.  With ``"planned"`` the backward is itself a scheduled
computation: a custom VJP replays the combined plan's B units over the
same ring (bitwise-equal gradients; group-level rematerialization is
inherent).

Bubble accounting comes from :mod:`repro.core.chunking`: choose the
(schedule, microbatch count) pair with
:func:`repro.core.chunking.optimal_schedule` (or just ``M`` with
:func:`repro.core.chunking.optimal_num_chunks`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core import chunking
from repro.core.graph import Stream
from repro.core.stream import FutureEvaluator, LazyEvaluator

PyTree = Any
StageFn = Callable[[PyTree, PyTree], PyTree]  # (stage_params, x) -> y


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int = 1
    num_microbatches: int = 1
    axis_name: str = "pod"
    remat: bool = True
    # Pipeline schedule: "gpipe", "one_f_one_b", or "interleaved".  With
    # "interleaved", each device owns `interleave` non-contiguous stage
    # groups; num_stages must stay divisible by (axis size * interleave).
    schedule: str = "gpipe"
    interleave: int = 1
    # How jax.grad flows through the pipeline: "autodiff" transposes the
    # forward tick scan; "planned" runs the combined plan's B units as
    # first-class scheduled work (custom VJP, bitwise-equal gradients) —
    # see repro.core.schedules.build_combined_plan.
    backward: str = "autodiff"

    def __post_init__(self):
        from repro.core.schedules import validate_backward, validate_schedule

        validate_schedule(self.schedule, self.interleave)
        validate_backward(self.backward)
        if self.num_stages % self.interleave != 0:
            raise ValueError(
                f"num_stages={self.num_stages} not divisible by "
                f"interleave={self.interleave}"
            )

    @property
    def bubble_fraction(self) -> float:
        """Modeled bubble under this config's schedule (num_stages is used
        as the device count; a synchronous h=1 hand-off is assumed — the
        classic figure.  The evaluator's measured plan is the ground
        truth: ``FutureEvaluator.plan_for(M).bubble_fraction``)."""
        return chunking.schedule_bubble_fraction(
            self.schedule,
            self.num_stages // self.interleave,
            self.num_microbatches,
            self.interleave,
            handoff=1,
        )

    @property
    def peak_stash_items(self) -> int:
        """Peak concurrently-stashed activations (in microbatches) per
        device under this config's backward mode — the combined plan's
        own stash bound for "planned", the scan transpose's V*M for
        "autodiff"."""
        return chunking.schedule_peak_items(
            self.schedule,
            self.num_stages // self.interleave,
            self.num_microbatches,
            self.interleave,
            backward=self.backward,
        )


def pipeline_apply(
    stage_fn: StageFn,
    stage_params: PyTree,
    x: PyTree,
    config: PipelineConfig,
    mesh: jax.sharding.Mesh | None = None,
) -> PyTree:
    """Run ``x`` through ``num_stages`` stages of ``stage_fn``.

    ``stage_params`` leaves must have leading axis ``num_stages``.  ``x``
    leaves have leading axis global-batch, chunked into
    ``num_microbatches`` items.  With ``mesh`` given, stages are pipelined
    over ``config.axis_name`` under ``config.schedule`` (Future);
    otherwise evaluated sequentially (Lazy).  Results are identical for
    every schedule.

    Routed through the StreamGraph IR: the stage stack is one algebra
    segment, so model code composes with ``map``/``zip``-built streams.
    """
    items = chunking.chunk_axis(x, config.num_microbatches)
    stream = Stream.source(items).through(
        lambda params, xb: (params, stage_fn(params, xb)),
        stage_params,
        num_cells=config.num_stages,
        mutable_state=False,
        remat=config.remat,
    )
    if mesh is None or config.num_stages == 1:
        evaluator = LazyEvaluator()
    else:
        evaluator = FutureEvaluator(
            mesh,
            config.axis_name,
            schedule=config.schedule,
            interleave=config.interleave,
            backward=config.backward,
        )
    out = stream.collect(evaluator).items
    return chunking.unchunk_axis(out)


def split_stages(layer_params: PyTree, num_layers: int, num_stages: int) -> PyTree:
    """Regroup per-layer stacked params (L, ...) into (num_stages, L/S, ...)."""
    if num_layers % num_stages != 0:
        raise ValueError(f"{num_layers=} not divisible by {num_stages=}")
    per = num_layers // num_stages

    def _split(p):
        return p.reshape((num_stages, per) + p.shape[1:])

    return jax.tree.map(_split, layer_params)


def merge_stages(stage_params: PyTree) -> PyTree:
    """Inverse of :func:`split_stages`."""
    return jax.tree.map(
        lambda p: p.reshape((-1,) + p.shape[2:]), stage_params
    )
