"""Future combinators at three levels of the TPU hierarchy.

The paper's ``Future[A]`` is a handle to a value being produced
asynchronously, forced by ``Await.result``.  JAX/XLA has no user-visible
threads, but it has the same concept at every level:

1. **Dataflow futures** (:class:`Future`): under ``jit`` every op is
   issued into a dataflow graph; a value "in flight" is simply one whose
   consumer hasn't been scheduled yet.  ``defer`` builds the value now
   (issuing its producer early), ``force`` pins a scheduling edge with
   ``lax.optimization_barrier`` so XLA cannot sink the producer to the
   consumption point — i.e. the async region is explicit, and on TPU the
   async collective/DMA actually overlaps the intervening compute.
2. **Collective futures** (``ppermute_future`` / ``all_gather_future``):
   issue the collective early, force late.  This is the manual
   compute/comm overlap idiom; XLA:TPU lowers these to async
   ``collective-permute-start/done`` pairs.
3. **Host futures** (:class:`HostFuture`): a thin wrapper over
   ``concurrent.futures`` used by the data pipeline (prefetch = the
   stream's future tail) and the checkpointer (async writes).

``jax.block_until_ready`` is the outermost ``Await.result``: JAX
dispatch is itself asynchronous, so every jitted call already returns a
future in the paper's sense.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

PyTree = Any


@dataclasses.dataclass
class Future:
    """A traced value plus an explicit not-yet-forced scheduling region."""

    _value: PyTree
    _forced: bool = False

    def map(self, f: Callable[[PyTree], PyTree]) -> "Future":
        """The Lazy/Future monad's ``map`` — forwards the asynchrony."""
        return Future(f(self._value), self._forced)

    def flat_map(self, f: Callable[[PyTree], "Future"]) -> "Future":
        return f(self._value)

    def force(self, anchor: PyTree | None = None) -> PyTree:
        """Await.result.

        If ``anchor`` is given, insert an optimization barrier tying the
        future's completion *after* the anchor's computation, making the
        overlap region explicit to XLA: compute(anchor) runs while the
        future's producer (e.g. an async collective) is in flight.
        """
        if anchor is None or self._forced:
            return self._value
        leaves, treedef = jax.tree.flatten(self._value)
        anchor_leaf = jax.tree.leaves(anchor)[0]
        # Barrier couples (value, anchor) so neither crosses the other.
        # (compat: 0.4.x lax.optimization_barrier has no AD rule; the
        # shim adds one so grad-through-pipeline works everywhere.)
        barriered = compat.optimization_barrier(tuple(leaves) + (anchor_leaf,))
        self._forced = True
        return jax.tree.unflatten(treedef, list(barriered[: len(leaves)]))


def defer(f: Callable[..., PyTree], *args, **kwargs) -> Future:
    """Issue ``f(*args)`` now; force its result later (paper's ``future``)."""
    return Future(f(*args, **kwargs))


def ppermute_future(x: PyTree, axis_name: str, perm) -> Future:
    """Start a collective-permute; force at the use site to overlap."""
    return defer(
        lambda t: jax.tree.map(lambda v: lax.ppermute(v, axis_name, perm), t), x
    )


def all_gather_future(x: PyTree, axis_name: str, *, tiled: bool = True) -> Future:
    """Start an all-gather; force at the use site to overlap."""
    return defer(
        lambda t: jax.tree.map(
            lambda v: lax.all_gather(v, axis_name, tiled=tiled), t
        ),
        x,
    )


def psum_scatter_future(x: PyTree, axis_name: str) -> Future:
    """Start a reduce-scatter; force at the use site to overlap."""
    return defer(
        lambda t: jax.tree.map(
            lambda v: lax.psum_scatter(v, axis_name, tiled=True), t
        ),
        x,
    )


class HostFuture:
    """Host-side future (data prefetch, async checkpoint writes)."""

    _pool = concurrent.futures.ThreadPoolExecutor(max_workers=4)

    def __init__(self, fn: Callable[[], Any]):
        self._fut = self._pool.submit(fn)

    def map(self, f: Callable[[Any], Any]) -> "HostFuture":
        fut = self._fut
        return HostFuture(lambda: f(fut.result()))

    def done(self) -> bool:
        return self._fut.done()

    def force(self, timeout: float | None = None) -> Any:
        return self._fut.result(timeout=timeout)
