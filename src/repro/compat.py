"""Version shims: one module owns every JAX-API fork in the repo.

The codebase is written against the modern JAX surface (``jax.shard_map``
with ``axis_names``, ``jax.sharding.AxisType``, ``lax.pcast`` vma casts,
``jax.sharding.set_mesh``/``get_abstract_mesh``, differentiable
``optimization_barrier``).  The pinned container ships JAX 0.4.37, where
each of those is missing or spelled differently.  Every call site routes
through here so the rest of the tree stays single-idiom:

========================  =============================  ====================
modern (>= 0.5/0.8)       0.4.x fallback                 shim
========================  =============================  ====================
jax.sharding.AxisType     (absent)                       enum stand-in
jax.make_mesh(axis_types) jax.make_mesh (no kwarg)       kwarg dropped
jax.shard_map(axis_names) jax.experimental.shard_map     manual set -> auto=
                          (auto=, check_rep=)            complement
lax.pcast                 (absent; no vma types)         identity
jax.sharding.set_mesh     ``with mesh:`` context         context manager
get_abstract_mesh         thread_resources physical mesh getter
optimization_barrier AD   NotImplementedError            custom_vjp wrapper
========================  =============================  ====================

Nothing here imports anything outside jax, so it is safe to import first.
"""
from __future__ import annotations

import contextlib
import contextvars
import enum
from typing import Any, Iterable

import jax
from jax import lax

PyTree = Any

# Manual axes of the shard_map region currently being traced (0.4.x has
# no mesh.axis_types to read them from; the shim records them instead).
_MANUAL_AXES: contextvars.ContextVar[frozenset] = contextvars.ContextVar(
    "repro_manual_axes", default=frozenset()
)


# ---------------------------------------------------------------------------
# AxisType / make_mesh
# ---------------------------------------------------------------------------

if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for jax.sharding.AxisType (absent in 0.4.x).

        0.4.x meshes are implicitly all-Auto; the enum exists so call
        sites can still *name* the intent and so ``manual_axis_names``
        has something to compare against on newer JAX.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(
    axis_shapes: tuple[int, ...],
    axis_names: tuple[str, ...],
    *,
    axis_types: tuple[Any, ...] | None = None,
    devices=None,
) -> jax.sharding.Mesh:
    """jax.make_mesh that tolerates the missing ``axis_types`` kwarg."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names, axis_types=axis_types, devices=devices
        )
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)


# ---------------------------------------------------------------------------
# shard_map (partial-manual spelling)
# ---------------------------------------------------------------------------


def shard_map(
    f,
    *,
    mesh: jax.sharding.Mesh,
    in_specs,
    out_specs,
    axis_names: Iterable[str] | None = None,
):
    """Partial-manual shard_map across JAX versions.

    ``axis_names`` is the *manual* set (modern spelling).  On 0.4.x it is
    translated to the experimental API's ``auto=`` complement, with
    ``check_rep=False`` (the 0.4.x rep checker rejects the ppermute ring
    + axis_index control flow the pipeline engine uses, and lacks
    transpose rules for some rep-checked collectives under grad).
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    manual = (
        frozenset(mesh.axis_names)
        if axis_names is None
        else frozenset(axis_names)
    )
    auto = frozenset(mesh.axis_names) - manual

    def traced(*args, **kw):
        # Record the manual set while the body traces so
        # manual_axis_names() (hence sharding.maybe_constrain) can drop
        # manual axes from activation specs — 0.4.x's replacement for
        # reading AxisType.Manual off the abstract mesh.
        token = _MANUAL_AXES.set(_MANUAL_AXES.get() | manual)
        try:
            return f(*args, **kw)
        finally:
            _MANUAL_AXES.reset(token)

    return _shard_map(
        traced,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
        auto=auto,
    )


def pcast(x, axis_names, *, to: str = "varying"):
    """lax.pcast on JAX that has varying-manual-axes types; identity before."""
    if hasattr(lax, "pcast"):
        return lax.pcast(x, tuple(axis_names), to=to)
    return x


# ---------------------------------------------------------------------------
# Mesh context / introspection
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh):
    """jax.sharding.set_mesh, or the legacy ``with mesh:`` context."""
    if hasattr(jax.sharding, "set_mesh"):
        with jax.sharding.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def get_abstract_mesh():
    """Mesh currently in scope, or None.

    Modern JAX: the abstract mesh.  0.4.x: the physical mesh installed by
    ``with mesh:`` (empty mesh when none), which exposes the same
    ``.empty`` / ``.axis_names`` / ``.shape`` surface the callers use.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


def manual_axis_names(mesh) -> frozenset[str]:
    """Names of Manual axes in scope: the mesh's own (modern JAX) plus any
    recorded by a 0.4.x partial-manual shard_map being traced."""
    types = getattr(mesh, "axis_types", None)
    if types is None:
        own = frozenset()
    elif isinstance(types, dict):  # some versions: {AxisType: names}
        manual = types.get(AxisType.Manual, ())
        own = frozenset((manual,) if isinstance(manual, str) else manual)
    else:
        own = frozenset(
            name
            for name, kind in zip(mesh.axis_names, types)
            if kind == AxisType.Manual
        )
    return own | _MANUAL_AXES.get()


# ---------------------------------------------------------------------------
# Differentiable optimization_barrier
# ---------------------------------------------------------------------------

# Trace-only probe (eval_shape): detects the missing 0.4.x AD rule
# without executing anything — importing repro must never initialize a
# backend or lock in the platform before the caller sets XLA_FLAGS.
try:
    jax.eval_shape(
        jax.grad(lambda x: lax.optimization_barrier((x,))[0]),
        jax.ShapeDtypeStruct((), "float32"),
    )
    _BARRIER_DIFFERENTIABLE = True
except Exception:  # noqa: BLE001  (0.4.x: NotImplementedError)
    _BARRIER_DIFFERENTIABLE = False


if _BARRIER_DIFFERENTIABLE:
    optimization_barrier = lax.optimization_barrier
else:

    @jax.custom_vjp
    def optimization_barrier(xs: tuple):
        """lax.optimization_barrier with an AD rule (absent in 0.4.x).

        Backward applies its own barrier to the cotangents: the reversed
        pipeline gets the same issue-early/force-late scheduling edge as
        the forward one.
        """
        return lax.optimization_barrier(xs)

    def _ob_fwd(xs):
        return lax.optimization_barrier(xs), None

    def _ob_bwd(_, cts):
        return (lax.optimization_barrier(cts),)

    optimization_barrier.defvjp(_ob_fwd, _ob_bwd)
