"""End-to-end serving driver: continuous batching over a token stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
        --requests 16 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params, param_count
from repro.serve.engine import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.embeds_input:
        raise SystemExit("embeds-input archs need the embedding frontend stub; "
                         "use a token arch for the serving example")
    rng = jax.random.PRNGKey(args.seed)
    params = init_params(rng, T.model_layout(cfg))
    print(f"arch={cfg.name} params={param_count(T.model_layout(cfg))/1e6:.1f}M")

    eng = Engine(params, cfg, ServeConfig(
        max_batch=args.max_batch, max_len=args.max_len,
        prefill_chunk=args.prefill_chunk, max_new_tokens=args.max_new,
        temperature=args.temperature, seed=args.seed,
    ))
    np_rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    reqs = [
        eng.submit(np_rng.integers(0, cfg.vocab_size, size=args.prompt_len))
        for _ in range(args.requests)
    ]
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {total_new} tokens in {wall:.2f}s "
          f"({total_new/wall:.1f} tok/s with continuous batching)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.out_tokens}")
    return done


if __name__ == "__main__":
    main()
