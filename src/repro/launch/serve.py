"""End-to-end serving driver: continuous batching over a token stream.

    # layer-sequential reference engine
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
        --requests 16 --max-new 12

    # Stream-shaped pipelined decode (cells sharded over the devices;
    # smoke configs have 2 layer groups, so deepen with --num-layers):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --num-layers 8 --engine stream --schedule interleaved \
        --interleave 2 --cells 8 --microbatches 4 --max-batch 8 \
        --round-steps 8 --devices 4

    # Resilient serving: supervised rounds with a watchdog deadline,
    # per-request deadlines, a bounded admission queue, and (here) a
    # chaos fault injected at round 2 to demonstrate zero-loss replay:
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 8 --deadline-ms 60000 --max-queue 64 \
        --watchdog-ms 30000 --chaos raise@2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import compat
from repro.configs.base import DecodePipelineConfig
from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params, param_count
from repro.serve.engine import Engine, ServeConfig, StreamEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # Stream-shaped serving knobs (DecodePipelineConfig)
    ap.add_argument("--engine", choices=("sequential", "stream"),
                    default="sequential")
    ap.add_argument("--schedule", default="gpipe",
                    choices=("gpipe", "one_f_one_b", "interleaved"))
    ap.add_argument("--interleave", type=int, default=1)
    ap.add_argument("--cells", type=int, default=4,
                    help="layer-group pipeline cells (must divide groups)")
    ap.add_argument("--microbatches", type=int, default=4,
                    help="in-flight request microbatches (feedback lag)")
    ap.add_argument("--round-steps", type=int, default=8,
                    help="decode steps per device-program invocation")
    ap.add_argument("--admit-per-round", type=int, default=4)
    ap.add_argument("--kernels", choices=("xla", "pallas", "auto"),
                    default="xla",
                    help="decode-path kernel dispatch (repro.kernels): "
                    "pallas = fused decode-attention + emit epilogue "
                    "(interpret-emulated off-TPU, bitwise equal); auto = "
                    "pallas on TPU, xla elsewhere")
    ap.add_argument("--devices", type=int, default=0,
                    help="pipeline devices for --engine stream "
                    "(0 = all; 1 = LazyEvaluator, layer-sequential)")
    ap.add_argument("--num-layers", type=int, default=0,
                    help="override layer count (smoke configs have only "
                    "2 groups — deepen them so --cells can split)")
    ap.add_argument("--suggest-schedule", action="store_true",
                    help="print chunking.optimal_schedule's pick with the "
                    "decode cache-traffic (per-tick copy-bytes) term "
                    "before serving; compute terms come from "
                    "--model-work/--model-overhead (measure with "
                    "`benchmarks.run --suite serve` — only the copy "
                    "bytes are derived from the model config)")
    ap.add_argument("--model-work", type=float, default=1e-3,
                    help="modeled serial decode-step seconds per item "
                    "for --suggest-schedule (an assumption, not a "
                    "measurement)")
    ap.add_argument("--model-overhead", type=float, default=1e-5,
                    help="modeled per-tick dispatch overhead seconds "
                    "for --suggest-schedule")
    ap.add_argument("--model-copy-gbps", type=float, default=50.0,
                    help="modeled cache write bandwidth (GB/s) for the "
                    "copy-bytes term")
    # Resilience knobs (repro.serve.supervisor / engine robustness)
    ap.add_argument("--deadline-ms", type=float, default=0,
                    help="per-request wall-clock deadline from submission "
                    "(0 = none); expired requests resolve with "
                    "status='expired' at the next step boundary")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue (0 = unbounded); a full "
                    "queue sheds load by rejecting submit")
    ap.add_argument("--watchdog-ms", type=float, default=0,
                    help="supervised-round watchdog deadline (0 = off); "
                    "setting it wraps the engine in a ServeSupervisor "
                    "with snapshot/replay fault recovery")
    ap.add_argument("--chaos", default=None, metavar="KIND@ROUND",
                    help="inject one fault for the recovery demo: "
                    "raise@K, nan@K, wedge@K, or sigterm@K (implies the "
                    "supervisor; see repro.serve.supervisor)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.num_layers:
        cfg = cfg.with_overrides(num_layers=args.num_layers)
    cfg = cfg.with_overrides(kernels=args.kernels)
    if cfg.embeds_input:
        raise SystemExit("embeds-input archs need the embedding frontend stub; "
                         "use a token arch for the serving example")
    rng = jax.random.PRNGKey(args.seed)
    params = init_params(rng, T.model_layout(cfg))
    print(f"arch={cfg.name} params={param_count(T.model_layout(cfg))/1e6:.1f}M")

    scfg = ServeConfig(
        max_batch=args.max_batch, max_len=args.max_len,
        prefill_chunk=args.prefill_chunk, max_new_tokens=args.max_new,
        temperature=args.temperature, seed=args.seed,
        max_queue=args.max_queue or None,
    )
    if args.engine == "stream":
        ndev = args.devices or jax.device_count()
        mesh = None
        if ndev > 1:
            mesh = compat.make_mesh(
                (ndev,), ("pod",), devices=jax.devices()[:ndev]
            )
        pcfg = DecodePipelineConfig(
            num_cells=args.cells, microbatches=args.microbatches,
            schedule=args.schedule, interleave=args.interleave,
            round_steps=args.round_steps, admit_per_round=args.admit_per_round,
        )
        if args.suggest_schedule and ndev <= 1:
            print(
                "suggest-schedule: skipped — needs > 1 pipeline device "
                "(set --devices/XLA device forcing); there is no "
                "(schedule, M, V) choice on one device"
            )
        if args.suggest_schedule and ndev > 1:
            from repro.serve.engine import (
                decode_copy_bytes_per_tick, suggest_decode_pipeline,
            )

            mb = max(1, args.max_batch // args.microbatches)
            pick = suggest_decode_pipeline(
                cfg, devices=ndev, work_per_item=args.model_work,
                per_tick_overhead=args.model_overhead, microbatch=mb,
                num_cells=args.cells, max_len=args.max_len,
                copy_bytes_per_second=args.model_copy_gbps * 1e9,
                max_chunks=args.max_batch,
            )
            rows_b = decode_copy_bytes_per_tick(cfg, mb, args.cells)
            slab_b = decode_copy_bytes_per_tick(
                cfg, mb, args.cells, row_scatter=False, max_len=args.max_len
            )
            print(
                f"cost-model pick (ASSUMING work/item={args.model_work}s, "
                f"tick overhead={args.model_overhead}s, "
                f"{args.model_copy_gbps:.0f} GB/s — override with "
                f"--model-*; only the copy bytes are config-derived): "
                f"{pick.schedule} M={pick.num_chunks} V={pick.interleave}; "
                f"per-tick cache rows ≈ {rows_b} B vs {slab_b} B under "
                f"the slab scheme"
            )
        eng = StreamEngine(params, cfg, scfg, pcfg, mesh=mesh)
        mode = (f"stream/{args.schedule}xV{args.interleave} D={ndev} "
                f"S={args.cells} M={args.microbatches} T={args.round_steps} "
                f"kernels={eng.kernels}")
    else:
        if args.suggest_schedule:
            print(
                "suggest-schedule: skipped — the cost model picks a "
                "pipeline (schedule, M, V); run with --engine stream"
            )
        eng = Engine(params, cfg, scfg)
        mode = "sequential"

    # Supervised serving: --chaos or --watchdog-ms wraps the engine in a
    # ServeSupervisor (round snapshot/replay, bounded retry, SIGTERM
    # drain).  Submission and drain go through the supervisor so its
    # bookkeeping sees every request.
    server, sup = eng, None
    if args.chaos or args.watchdog_ms:
        from repro.serve.supervisor import (
            ServeSupervisor, SupervisorConfig, chaos_injector,
        )

        injector = None
        if args.chaos:
            try:
                kind, at = args.chaos.rsplit("@", 1)
                injector = chaos_injector(kind, int(at))
            except ValueError as e:
                raise SystemExit(f"--chaos expects KIND@ROUND: {e}")
        sup = ServeSupervisor(
            eng,
            SupervisorConfig(
                deadline_s=(args.watchdog_ms / 1e3) or None,
            ),
            fail_injector=injector,
        )
        sup.install_signal_handlers()
        server = sup
        mode += " +supervised"

    np_rng = np.random.default_rng(args.seed)
    deadline_s = (args.deadline_ms / 1e3) or None
    t0 = time.perf_counter()
    reqs, shed = [], 0
    from repro.serve.engine import QueueFullError

    for _ in range(args.requests):
        prompt = np_rng.integers(0, cfg.vocab_size, size=args.prompt_len)
        try:
            reqs.append(eng.submit(prompt, deadline_s=deadline_s)
                        if sup is None
                        else sup.submit(prompt, deadline_s=deadline_s))
        except QueueFullError:
            shed += 1
    done = server.run_until_drained()
    wall = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    expired = sum(r.status == "expired" for r in done)
    print(f"[{mode}] {len(done)} requests, {total_new} tokens in {wall:.2f}s "
          f"({total_new/wall:.1f} tok/s with continuous batching)")
    if shed or expired:
        print(f"  load_shed={shed} expired={expired}")
    if sup is not None:
        print(f"  supervisor: {sup.stats}")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.out_tokens}")
    return done


if __name__ == "__main__":
    main()
