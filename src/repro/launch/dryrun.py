import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 host-platform placeholders.

Per cell this driver:
  1. builds the production mesh (16×16 or 2×16×16),
  2. builds abstract inputs (ShapeDtypeStruct + NamedSharding — no
     allocation; the 398 B configs never materialize),
  3. ``jax.jit(step).lower(...).compile()`` — sharding propagation, SPMD
     partitioning and scheduling all run for real; failures here are
     system bugs,
  4. records ``memory_analysis()`` (fits-on-chip proof),
     ``cost_analysis()`` (FLOPs/bytes) and HLO collective bytes
     (roofline terms) to ``experiments/dryrun/<cell>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all  [--multi-pod-only]
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import SHAPES
from repro.configs.registry import all_cells, get_config
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.params import param_count
from repro.parallel import sharding as SH
from repro.roofline import analysis as RL
from repro.roofline import analytic as AN
from repro.roofline import hlo_parse as HP
from repro.train import optimizer as O
from repro.train.train_step import TrainConfig, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")


def cell_rules(shape_name: str):
    if shape_name == "long_500k":
        return SH.LONG_DECODE_RULES
    if SHAPES[shape_name].kind == "decode":
        return SH.DECODE_RULES
    if SHAPES[shape_name].kind == "prefill":
        return SH.PREFILL_RULES
    return SH.TRAIN_RULES


def train_configs_for(cfg):
    """Production microbatching/dtype policy per model size."""
    big = param_count(T.model_layout(cfg)) > 90e9
    # §Perf iteration 2: fewer/bigger microbatches — per-microbatch fixed
    # collectives (ZeRO-3 weight all-gathers, grad reductions) dominate the
    # collective term and scale linearly with the count.  Iteration 6:
    # microbatch count targets a fixed ~256k tokens per microbatch (the
    # paper's §7 chunk-size rule, applied via optimal_num_chunks logic):
    # a size-blind global count regressed the memory term on mid models
    # (qwen3 train 118→172 s at µb=2) while big models were already at
    # the target.  Divisibility walked down from the target.
    tokens = SHAPES["train_4k"].tokens
    num_micro = max(1, tokens // 262144)
    while SHAPES["train_4k"].global_batch % num_micro != 0:
        num_micro -= 1
    tcfg = TrainConfig(
        num_microbatches=num_micro,
        accum_dtype=jnp.bfloat16 if big else jnp.float32,
        attn_impl="chunked",
        remat=True,
        unroll=False,  # rolled scans; loop-aware HLO analysis scales bodies
        # §Perf iteration 6: causal block skipping stays ON for forward-only
        # paths (prefill: pure win) but OFF for training — the pair scan's
        # backward carry traffic outweighs the halved attention FLOPs on
        # memory-bound train cells (qwen3: mem 172.9 -> 118.5 s).
        causal_skip=False,
    )
    ocfg = O.AdamWConfig(
        moment_dtype=jnp.bfloat16 if big else jnp.float32
    )
    return tcfg, ocfg





def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = cell_rules(shape_name)
    tcfg, ocfg = train_configs_for(cfg)

    layout = T.model_layout(cfg)
    pspecs = SH.param_pspecs(layout, rules, mesh)

    def sh_of(tree):
        return jax.tree.map(lambda s: s.sharding, tree,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    scale = 1
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(cfg, tcfg, ocfg, param_pspecs=pspecs)
            a_params, a_opt = SP.abstract_model_state(cfg, ocfg, rules, mesh)
            batch_structs, batch_axes = SP.batch_struct(cfg, shape)
            a_batch = SP.sharded(batch_structs, batch_axes, rules, mesh)
            jitted = jax.jit(
                step, donate_argnums=(0, 1),
                out_shardings=(sh_of(a_params), sh_of(a_opt), None),
            )
            lowered = jitted.lower(a_params, a_opt, a_batch)
        elif shape.kind == "prefill":
            a_params, _ = SP.abstract_model_state(cfg, ocfg, rules, mesh)
            a_caches = SP.abstract_cache(cfg, shape, rules, mesh)
            a_in = SP.prefill_inputs(cfg, shape, rules, mesh)
            step = partial(
                T.prefill_step, cfg=cfg, attn_impl="chunked",
                q_chunk=512, kv_chunk=1024,
            )
            jitted = jax.jit(
                step, donate_argnums=(1,),
                out_shardings=(None, sh_of(a_caches)),
            )
            lowered = jitted.lower(a_params, a_caches, pos=0, **a_in)
        else:  # decode
            a_params, _ = SP.abstract_model_state(cfg, ocfg, rules, mesh)
            a_caches = SP.abstract_cache(cfg, shape, rules, mesh)
            a_in = SP.decode_inputs(cfg, shape, rules, mesh)
            # §Perf iteration 5: decode uses dense attention — q=1 scores
            # against the seq-sharded cache stay shard-local with tiny
            # (B,1,KV,G) stat reductions (flash-decoding via GSPMD); the
            # chunked kv scan's traced-offset slices forced fp32 all-
            # gathers of the whole cache (2×64 GiB/step on qwen3).
            step = partial(T.decode_step, cfg=cfg, attn_impl="dense")
            jitted = jax.jit(
                step, donate_argnums=(1,),
                out_shardings=(None, sh_of(a_caches)),
            )
            lowered = jitted.lower(a_params, a_caches, **a_in)

    return cfg, shape, lowered, scale, tcfg


def _analytic_state_gib(cfg, shape, tcfg, chips):
    """params + moments + grad accumulator + saved activation stack, per chip."""
    layout = T.model_layout(cfg)
    n = param_count(layout)
    bytes_total = n * 2            # bf16 params
    moment_b = 2 if tcfg.accum_dtype == jnp.bfloat16 else 4
    if shape.kind == "train":
        bytes_total += 2 * n * moment_b        # adam m, v
        accum_b = 2 if tcfg.accum_dtype == jnp.bfloat16 else 4
        bytes_total += n * accum_b             # grad accumulator
        groups = cfg.num_layers // max(1, T.effective_period(cfg))
        tokens_mb = shape.tokens // tcfg.num_microbatches
        bytes_total_act = groups * tokens_mb * cfg.d_model * 2  # saved stack
        return (bytes_total / chips + bytes_total_act / chips) / 2**30
    if shape.kind == "decode":
        # params + caches handled in args; just params here
        return (bytes_total / chips) / 2**30
    return (bytes_total / chips) / 2**30


def analyze(arch, shape_name, mesh_name, cfg, shape, lowered, scale, tcfg):
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    hp = HP.analyze_hlo(hlo)  # loop-aware collectives + HBM traffic

    chips = 512 if mesh_name == "multipod" else 256
    layout = T.model_layout(cfg)
    n_active = RL.active_param_count(cfg, layout)
    mflops = RL.model_flops(cfg, shape, n_active)
    # analytic count mirrors the lowering's causal-skip policy (iter. 6):
    # prefill auto-skips (forward-only); train lowers with skip off.
    skip = shape.kind == "prefill" or (
        shape.kind == "train" and bool(tcfg.causal_skip)
    )
    analytic = AN.step_flops(cfg, shape, remat=tcfg.remat, causal_skip=skip)
    raw_flops = float(cost.get("flops", 0.0))

    terms = RL.RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=raw_flops,
        hlo_bytes=hp["hbm_traffic_bytes"],
        collective_bytes=hp["collective_weighted_bytes"],
        model_flops=mflops,
        analytic_flops=analytic["total"],
    ).finalize()

    record = {
        "cell": f"{arch}×{shape_name}×{mesh_name}",
        "compile_seconds": None,
        "memory_analysis": {
            "argument_size_gib": mem.argument_size_in_bytes / 2**30,
            "output_size_gib": mem.output_size_in_bytes / 2**30,
            "temp_size_gib": mem.temp_size_in_bytes / 2**30,
            "peak_gib": (
                mem.argument_size_in_bytes
                + mem.temp_size_in_bytes
            ) / 2**30,
            # Decomposed estimate of the real per-chip residency (the CPU
            # backend's temp figure includes scatter-lowering key buffers
            # and fp32 cotangent copies a TPU lowering does not hold; see
            # EXPERIMENTS.md §Dry-run "memory methodology").
            "analytic_state_gib": _analytic_state_gib(cfg, shape, tcfg, chips),
        },
        "cost_analysis": {
            "flops_raw_hlo": raw_flops,
            "analytic_flops": analytic["total"],
            "analytic_breakdown": analytic["forward"],
            "xla_bytes_accessed_raw": float(cost.get("bytes accessed", 0.0)),
        },
        "hlo_analysis": {
            "hbm_traffic_gib": hp["hbm_traffic_bytes"] / 2**30,
            "collective_weighted_gib": hp["collective_weighted_bytes"] / 2**30,
            "collective_bytes_by_kind": hp["collective_bytes_by_kind"],
            "collective_counts_static": hp["collective_counts_static"],
            "collective_counts_dynamic": hp["collective_counts_dynamic"],
            "num_loops": hp["num_loops"],
            "top_collectives": hp["top_collectives"],
        },
        "roofline": terms.to_json(),
        "params_total": param_count(layout),
        "params_active": n_active,
    }
    return record, compiled


def run_cell(arch, shape_name, multi_pod: bool, save=True, verbose=True):
    mesh_name = "multipod" if multi_pod else "pod"
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, shape, lowered, scale, tcfg = lower_cell(arch, shape_name, mesh, mesh_name)
    record, compiled = analyze(
        arch, shape_name, mesh_name, cfg, shape, lowered, scale, tcfg
    )
    record["compile_seconds"] = time.perf_counter() - t0
    if verbose:
        r = record["roofline"]
        print(
            f"{arch:28s} {shape_name:12s} {mesh_name:8s} "
            f"peak {record['memory_analysis']['peak_gib']:7.2f} GiB  "
            f"compute {r['compute_s']*1e3:9.3f} ms  "
            f"memory {r['memory_s']*1e3:9.3f} ms  "
            f"collective {r['collective_s']*1e3:9.3f} ms  "
            f"-> {r['bottleneck']}"
        )
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_name}.json".replace("/", "_")
        with open(os.path.join(ARTIFACT_DIR, fname), "w") as f:
            json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]
    if args.multi_pod:
        meshes = [True]

    failures = []
    for arch, shape_name in cells:
        for multi_pod in meshes:
            try:
                run_cell(arch, shape_name, multi_pod)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape_name, multi_pod, repr(e)))
                print(f"FAIL {arch} {shape_name} multipod={multi_pod}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
