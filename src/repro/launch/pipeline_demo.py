import os
_SMALL = os.environ.get("PIPE_SMALL", "0") == "1"
if "dryrun" not in os.environ.get("_REPRO_DEVICES_SET", ""):
    count = "8" if _SMALL else "512"
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={count}"
    os.environ["_REPRO_DEVICES_SET"] = "dryrun"

"""Multi-pod STREAM-FUTURE mode: layer pipeline across the pod axis.

This is the paper's technique as the production cross-pod schedule
(DESIGN §4 mode (b)): stages = contiguous layer-group spans of a real
architecture, items = microbatches, tails = ppermute'd activations on the
inter-pod links; FSDP×TP sharding stays automatic *inside* each stage
(partial-manual shard_map).  jax.grad through the schedule yields the
backward pipeline (GPipe by autodiff), rematerialized per (cell, item).

The dry-run lowers + compiles the full train step of qwen3-32b at
train_4k on the 2×16×16 mesh with stages=2 over 'pod', and records the
same roofline artifacts as the baseline DP-over-pod mode for comparison.

    PYTHONPATH=src python -m repro.launch.pipeline_demo

NB toolchain: the partial-manual (pod=manual, data/model=auto) region of
a full transformer trips a hard CHECK (`sharding.IsManualSubgroup()`) in
XLA <= 0.4.37's SPMD partitioner — this dry-run needs the newer jaxlib
the seed targeted.  Single-axis (fully manual) pipelines, i.e. every
tier-1 path, compile fine on either toolchain via repro.compat.
"""
import json
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.core.pipeline import pipeline_apply
from repro.launch import specs as SP
from repro.launch.dryrun import ARTIFACT_DIR
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models import layers as L
from repro.models.params import abstract_params
from repro.parallel import sharding as SH
from repro.roofline import analysis as RL
from repro.roofline import analytic as AN
from repro.roofline import hlo_parse as HP
from repro.train import optimizer as O
from repro.train.train_step import TrainConfig

NUM_MICRO = 8
ARCH = os.environ.get("PIPE_ARCH", "qwen3-32b")
ATTN = os.environ.get("PIPE_ATTN", "chunked")
SHAPE = "train_4k"
REMAT = os.environ.get("PIPE_REMAT", "1") == "1"
# Pipeline schedule knobs (see repro.core.schedules): gpipe (default),
# one_f_one_b, or interleaved with PIPE_INTERLEAVE groups per device.
# PIPE_STAGES is the number of *stage groups* of the model; it must be
# (pod axis size x PIPE_INTERLEAVE), so the interleaved demo over the
# 2-pod mesh is PIPE_SCHEDULE=interleaved PIPE_INTERLEAVE=2 PIPE_STAGES=4.
# PIPE_BACKWARD selects the backward execution: "autodiff" (jax.grad
# transposes the forward plan) or "planned" (the combined plan's B units
# run through the custom-VJP engine — true 1F1B, min(S, M) stash).
SCHEDULE = os.environ.get("PIPE_SCHEDULE", "gpipe")
INTERLEAVE = int(os.environ.get("PIPE_INTERLEAVE", "1"))
NUM_STAGES = int(os.environ.get("PIPE_STAGES", str(2 * INTERLEAVE)))
BACKWARD = os.environ.get("PIPE_BACKWARD", "autodiff")


def _train_config():
    return TrainConfig(
        num_microbatches=NUM_MICRO, remat=REMAT,
        pipeline_schedule=SCHEDULE, pipeline_interleave=INTERLEAVE,
        pipeline_backward=BACKWARD,
    )


def staged_blocks_abstract(cfg, rules, mesh):
    """Abstract block params reshaped (G, ...) -> (stages, G/S, ...) with the
    stage axis sharded over 'pod'."""
    layout = T.model_layout(cfg)
    a = abstract_params(layout)
    specs = SH.param_pspecs(layout, rules, mesh)

    def stage_leaf(struct, spec):
        groups = struct.shape[0]
        assert groups % NUM_STAGES == 0
        shape = (NUM_STAGES, groups // NUM_STAGES) + struct.shape[1:]
        pspec = jax.sharding.PartitionSpec("pod", *spec)
        pspec = SH.fit_spec(pspec, shape, mesh)
        return jax.ShapeDtypeStruct(
            shape, struct.dtype, sharding=jax.sharding.NamedSharding(mesh, pspec)
        )

    blocks = jax.tree.map(
        stage_leaf, a["blocks"], specs["blocks"],
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    rest = {}
    for key in ("embed", "final_norm", "head"):
        rest[key] = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype,
                sharding=jax.sharding.NamedSharding(
                    mesh, SH.fit_spec(sp, s.shape, mesh)
                ),
            ),
            a[key], specs[key],
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
    return {"blocks": blocks, **rest}


def make_pipelined_loss(cfg, mesh):
    plans = T.block_plans(cfg)
    pcfg = _train_config().pipeline_config(NUM_STAGES, axis_name="pod")

    def stage_fn(stage_params, x):
        positions = jnp.arange(x.shape[1])[None, :]

        def group_fn(x, group_params):
            x, _, _ = _group(group_params, x)
            return x, None

        def _group(group_params, x):
            return T._apply_group(
                group_params, x, cfg, plans, positions=positions,
                attn_impl=ATTN, q_chunk=512, kv_chunk=1024,
            )

        x, _ = jax.lax.scan(group_fn, x, stage_params)
        return x

    def loss_fn(params, batch):
        x = L.embed_lookup(params["embed"]["embedding"], batch["tokens"])
        x = pipeline_apply(stage_fn, params["blocks"], x, pcfg, mesh=mesh)
        x = T._norm(cfg, params.get("final_norm"), x)
        logits = L.logits(params["head"], params["embed"], x, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        vocab_iota = jnp.arange(logits.shape[-1], dtype=batch["labels"].dtype)
        gold = jnp.sum(
            jnp.where(vocab_iota == batch["labels"][..., None], logits, 0.0),
            axis=-1,
        )
        return jnp.mean(lse - gold)

    def train_step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # sgd-style apply keeps the demo focused on the pipeline schedule
        params = jax.tree.map(
            lambda p, g: (p - 1e-3 * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return params, loss

    return train_step


def main():
    if _SMALL:
        mesh = compat.make_mesh(
            (2, 2, 2), ("pod", "data", "model"),
            axis_types=(compat.AxisType.Auto,) * 3,
        )
    else:
        mesh = make_production_mesh(multi_pod=True)
    cfg = get_config(ARCH)
    # XLA:CPU CHECK-fails ("Invalid binary instruction opcode copy",
    # hlo_instruction.cc:1558) partitioning bf16 cotangents inside a
    # partial-manual shard_map; bisected to bf16+grad+pipeline — f32
    # compiles.  Lower the demo in f32 and halve its byte metrics when
    # comparing against bf16 baselines (EXPERIMENTS §Perf).
    cfg = cfg.with_overrides(dtype=jnp.float32)
    shape = SHAPES[SHAPE]
    if _SMALL:
        import dataclasses
        shape = dataclasses.replace(shape, global_batch=16, seq_len=512)
    rules = dict(SH.TRAIN_RULES, batch="data")  # pod is the pipeline axis
    a_params = staged_blocks_abstract(cfg, rules, mesh)
    bs, ba = SP.batch_struct(cfg, shape)
    a_batch = SP.sharded(bs, ba, rules, mesh)

    step = make_pipelined_loss(cfg, mesh)
    t0 = time.perf_counter()
    with compat.set_mesh(mesh):
        lowered = jax.jit(step, donate_argnums=(0,)).lower(a_params, a_batch)
        compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    hp = HP.analyze_hlo(compiled.as_text())
    analytic = AN.step_flops(cfg, shape, remat=True, causal_skip=True)
    import dataclasses
    pcfg = _train_config().pipeline_config(NUM_STAGES)
    autodiff_stash = dataclasses.replace(
        pcfg, backward="autodiff"
    ).peak_stash_items
    record = {
        "cell": f"{ARCH}×{SHAPE}×multipod-PIPELINE",
        "mode": f"stream-future pipeline: stages={NUM_STAGES} over 'pod', "
                f"microbatches={NUM_MICRO}, schedule={SCHEDULE}"
                f"x{INTERLEAVE}, backward={BACKWARD}, bubble="
                f"{pcfg.bubble_fraction:.3f}, "
                f"peak_stash={pcfg.peak_stash_items}/{NUM_MICRO}",
        "compile_seconds": compile_s,
        "memory_analysis": {
            "argument_size_gib": mem.argument_size_in_bytes / 2**30,
            "temp_size_gib": mem.temp_size_in_bytes / 2**30,
        },
        "hlo_analysis": {
            "hbm_traffic_gib": hp["hbm_traffic_bytes"] / 2**30,
            "collective_weighted_gib": hp["collective_weighted_bytes"] / 2**30,
            "collective_bytes_by_kind": hp["collective_bytes_by_kind"],
            "top_collectives": hp["top_collectives"][:6],
        },
        "analytic_flops": analytic["total"],
    }
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR, f"{ARCH}_{SHAPE}_pipeline.json"), "w") as f:
        json.dump(record, f, indent=2)
    print(json.dumps(record["hlo_analysis"]["collective_bytes_by_kind"], indent=1))
    print(f"pipeline dry-run compiled in {compile_s:.0f}s; "
          f"collective {hp['collective_weighted_bytes']/2**30:.0f} GiB, "
          f"hbm {hp['hbm_traffic_bytes']/2**30:.0f} GiB per device")
    print(f"schedule {SCHEDULE}x{INTERLEAVE} backward={BACKWARD}: "
          f"combined-plan stash bound {pcfg.peak_stash_items}/{NUM_MICRO} "
          f"microbatches per device "
          f"(autodiff keeps {autodiff_stash}/{NUM_MICRO} live; the bound "
          f"is proven by the plan's stash/release columns and realized "
          f"by a fused executor — see schedules.CombinedPlan)")


if __name__ == "__main__":
    main()
