"""ShapeDtypeStruct input specs per (arch × shape) cell.

Everything the dry-run lowers is declared here: abstract params, optimizer
state, batches, caches — with logical axes resolved to NamedShardings via
the rule sets in :mod:`repro.parallel.sharding`.  No device allocation.

Modality frontends are stubs per the assignment: the VLM's
``vision_embeds`` and the audio model's frame ``embeds`` arrive as
precomputed embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeCell
from repro.models import transformer as T
from repro.models.params import abstract_params
from repro.parallel import sharding as SH
from repro.train import optimizer as O

PyTree = Any


def batch_struct(cfg: ArchConfig, shape: ShapeCell) -> tuple[PyTree, PyTree]:
    """(ShapeDtypeStructs, logical-axes tree) for one training batch."""
    b, s = shape.global_batch, shape.seq_len
    structs: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    if cfg.embeds_input:
        structs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        axes["embeds"] = ("batch", "seq", None)
    else:
        structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    structs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    axes["labels"] = ("batch", "seq")
    if cfg.vision_tokens:
        structs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
        axes["vision_embeds"] = ("batch", None, None)
    return structs, axes


def sharded(structs: PyTree, axes: PyTree, rules, mesh) -> PyTree:
    """Attach NamedShardings to ShapeDtypeStructs by logical axes."""

    def one(struct, ax):
        spec = SH.fit_spec(SH.spec_for(ax, rules), struct.shape, mesh)
        return jax.ShapeDtypeStruct(
            struct.shape, struct.dtype, sharding=NamedSharding(mesh, spec)
        )

    return jax.tree.map(one, structs, axes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_model_state(cfg: ArchConfig, ocfg: O.AdamWConfig, rules, mesh):
    """(abstract params, abstract opt state) with shardings attached."""
    layout = T.model_layout(cfg)
    a_params = abstract_params(layout)
    shardings = SH.param_shardings(layout, rules, mesh)
    a_params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        a_params,
        shardings,
    )
    a_opt = O.abstract_opt_state(a_params, ocfg)
    # moments share the param shardings; step is replicated
    a_opt = {
        "m": jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            a_opt["m"], shardings,
        ),
        "v": jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            a_opt["v"], shardings,
        ),
        "step": jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, P())
        ),
    }
    return a_params, a_opt


def abstract_cache(cfg: ArchConfig, shape: ShapeCell, rules, mesh):
    caches = T.cache_layout(cfg, shape.global_batch, shape.seq_len)
    axes = T.cache_logical_axes(cfg)
    return sharded(caches, axes, rules, mesh)


def decode_inputs(cfg: ArchConfig, shape: ShapeCell, rules, mesh):
    b = shape.global_batch
    batch_spec = SH.prune_spec(SH.spec_for(("batch",), rules), mesh)
    structs = {
        "lengths": jax.ShapeDtypeStruct(
            (b,), jnp.int32, sharding=NamedSharding(mesh, batch_spec)
        ),
    }
    if cfg.embeds_input:
        structs["embeds"] = jax.ShapeDtypeStruct(
            (b, 1, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, SH.prune_spec(SH.spec_for(("batch", None, None), rules), mesh)),
        )
    else:
        structs["tokens"] = jax.ShapeDtypeStruct(
            (b,), jnp.int32, sharding=NamedSharding(mesh, batch_spec)
        )
    return structs


def prefill_inputs(cfg: ArchConfig, shape: ShapeCell, rules, mesh):
    b, s = shape.global_batch, shape.seq_len
    structs: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    if cfg.embeds_input:
        structs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        axes["embeds"] = ("batch", "seq", None)
    else:
        structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    if cfg.vision_tokens:
        structs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
        )
        axes["vision_embeds"] = ("batch", None, None)
    return sharded(structs, axes, rules, mesh)
