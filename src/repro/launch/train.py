"""End-to-end training driver.

Wires every substrate layer together: config registry → mesh → sharded
params/optimizer → step-keyed data pipeline with prefetch (future tails) →
jitted train step (microbatch stream) → resilient loop (heartbeats,
straggler detection, async checkpoints, restart-on-failure).

CPU-scale example (the quickstart path, ~25M params):
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 100 --global-batch 8 --seq-len 256
Production shapes lower through the same code path (see dryrun.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.data.pipeline import DataConfig, PrefetchIterator, make_source
from repro.models import transformer as T
from repro.models.params import init_params, param_count
from repro.parallel import sharding as SH
from repro.train.checkpoint import Checkpointer
from repro.train.fault import FaultConfig, ResilientLoop
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step


def build(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
        cfg = cfg.with_overrides(
            d_model=args.d_model or 256,
            num_layers=args.layers or cfg.num_layers,
            d_ff=4 * (args.d_model or 256) if cfg.d_ff else 0,
            vocab_size=1024,
        )
    tcfg = TrainConfig(
        num_microbatches=args.microbatches,
        attn_impl=args.attn_impl,
        remat=True,
        pipeline_schedule=args.pipeline_schedule,
        pipeline_backward=args.pipeline_backward,
        kernels=args.kernels,
    )
    ocfg = AdamWConfig(
        learning_rate=args.lr, warmup_steps=args.warmup,
        total_steps=args.steps,
    )
    return cfg, tcfg, ocfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--attn-impl", default="dense",
                    choices=["dense", "chunked", "pallas"])
    ap.add_argument("--pipeline-schedule", default="gpipe",
                    choices=["gpipe", "one_f_one_b", "interleaved"],
                    help="layer-pipeline tick schedule (multi-pod mode)")
    ap.add_argument("--pipeline-backward", default="autodiff",
                    choices=["autodiff", "planned"],
                    help="backward execution: jax.grad transpose of the "
                         "forward plan, or the combined plan's B units "
                         "through the custom-VJP engine (true 1F1B)")
    ap.add_argument("--kernels", choices=["xla", "pallas", "auto"],
                    default="xla",
                    help="kernel dispatch (repro.kernels). Training "
                         "requires xla (Pallas kernels have no VJPs); "
                         "pallas fails fast with a clear error, auto "
                         "resolves to xla")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, tcfg, ocfg = build(args)
    layout = T.model_layout(cfg)
    print(f"arch={cfg.name} params={param_count(layout)/1e6:.1f}M "
          f"devices={jax.device_count()}")
    if tcfg.num_microbatches > 1:
        # Surface the schedule's memory bound (4-stage reference split —
        # this CPU driver itself runs unpipelined; the multi-pod driver
        # is launch.pipeline_demo): the combined plan's stash bound vs
        # what autodiff keeps live.  Plan-level: the bound a fused
        # executor realizes; the two-phase custom-VJP realization holds
        # V*M at the autodiff phase boundary (see CombinedPlan).
        pcfg = tcfg.pipeline_config(num_stages=4)
        auto = dataclasses.replace(pcfg, backward="autodiff").peak_stash_items
        print(f"pipeline: schedule={tcfg.pipeline_schedule} "
              f"backward={tcfg.pipeline_backward} -> combined-plan stash "
              f"bound {pcfg.peak_stash_items}/{tcfg.num_microbatches} "
              f"microbatches per device at a 4-stage split (autodiff "
              f"keeps {auto}/{tcfg.num_microbatches} live)")

    rng = jax.random.PRNGKey(args.seed)
    params = init_params(rng, layout)
    opt_state = init_opt_state(params, ocfg)

    # data: step-keyed, prefetched
    dcfg = DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        seed=args.seed, vocab_size=cfg.vocab_size,
    )
    source = make_source(dcfg)

    def batch_fn(step):
        b = source.batch(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    step_fn = jax.jit(make_train_step(cfg, tcfg, ocfg), donate_argnums=(0, 1))

    ckpt = Checkpointer(args.checkpoint_dir)
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(
            {"params": params, "opt_state": opt_state}
        )
        params, opt_state = state["params"], state["opt_state"]
        print(f"resumed from step {start_step}")

    loop = ResilientLoop(
        step_fn, ckpt,
        FaultConfig(checkpoint_every=args.checkpoint_every,
                    heartbeat_path=args.checkpoint_dir + "/heartbeat"),
    )
    loop.install_signal_handlers()

    t0 = time.perf_counter()
    params, opt_state, step, history = loop.run(
        params, opt_state, batch_fn, args.steps, start_step=start_step
    )
    wall = time.perf_counter() - t0
    for h in history[:: args.log_every]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  lr {h['learning_rate']:.2e}")
    if history:
        print(f"final loss {history[-1]['loss']:.4f}  "
              f"({wall/max(1,len(history)):.2f}s/step, "
              f"restarts={loop.stats['restarts']}, "
              f"stragglers={loop.stats['stragglers']})")
    return history


if __name__ == "__main__":
    main()
