"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis rides
inter-pod links and is used either for cross-pod data parallelism
(gradient all-reduce, compressed) or as the stream-future pipeline axis
(see repro.core.pipeline).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state; callers own the
``XLA_FLAGS=--xla_force_host_platform_device_count=...`` dance (dryrun.py
sets it before any jax import, per the runbook).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(axis_name: str = "pod") -> jax.sharding.Mesh:
    """All local devices on one axis (CPU tests / examples)."""
    return compat.make_mesh(
        (jax.device_count(),), (axis_name,),
        axis_types=(compat.AxisType.Auto,),
    )
