"""Fixed-width multi-limb unsigned integers in JAX (base 2^13, int32 limbs).

The paper's ``stream_big`` variant multiplies every coefficient by
100000000001 (~2^37) "in order to increase the footprint of elementary
operations" — JVM ``BigInteger`` arithmetic.  XLA has no arbitrary
precision, so we carry fixed-width multi-limb integers: a number is
``(L,)`` int32 limbs, little-endian, each in ``[0, 2^13)``.

Base 2^13 keeps every intermediate inside int32 without x64:
  * limb product  < 2^26
  * sum of up to 32 limb products or carries < 2^31 ✓ (L ≤ 32 enforced)

The limb count L is the *footprint knob*: L=4 (52 bits) for ``stream``,
L=12 (156 bits) for ``stream_big`` — reproducing the paper's small/big
coefficient regimes on SIMD hardware.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

LIMB_BITS = 13
LIMB_BASE = 1 << LIMB_BITS
LIMB_MASK = LIMB_BASE - 1
MAX_LIMBS = 32


def from_int(value: int, num_limbs: int) -> jnp.ndarray:
    """Python int (arbitrary precision) -> limb vector. Raises on overflow."""
    if value < 0:
        raise ValueError("unsigned limb integers only")
    limbs = []
    v = int(value)
    for _ in range(num_limbs):
        limbs.append(v & LIMB_MASK)
        v >>= LIMB_BITS
    if v:
        raise OverflowError(f"{value} does not fit in {num_limbs} limbs")
    return jnp.asarray(limbs, jnp.int32)


def to_int(limbs) -> int:
    """Limb vector -> Python int (host-side; exact)."""
    out = 0
    for limb in reversed(np.asarray(limbs).tolist()):
        out = (out << LIMB_BITS) | int(limb)
    return out


def normalize(raw: jnp.ndarray) -> jnp.ndarray:
    """Carry-propagate (..., L) int32 limbs that may exceed the base.

    A fixed L-1 sweep fully propagates carries produced by one add/mul
    round (each carry is < base after the first sweep).
    """
    num_limbs = raw.shape[-1]
    out = raw
    for _ in range(num_limbs):  # full ripple worst case
        carry = out >> LIMB_BITS
        out = (out & LIMB_MASK) + jnp.concatenate(
            [jnp.zeros_like(carry[..., :1]), carry[..., :-1]], axis=-1
        )
    # Any residual carry out of the top limb is overflow; truncated (mod 2^(13L)).
    return out & LIMB_MASK


def add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(..., L) + (..., L) -> (..., L), mod 2^(13L)."""
    return normalize(a + b)


def mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(..., L) * (..., L) -> (..., L) low limbs, mod 2^(13L).

    Schoolbook convolution, accumulated per output limb with staged
    normalization every 16 partial products to stay inside int32.
    """
    num_limbs = a.shape[-1]
    if num_limbs > MAX_LIMBS:
        raise ValueError(f"L={num_limbs} exceeds MAX_LIMBS={MAX_LIMBS}")
    out = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), jnp.int32)
    acc = out
    for j in range(num_limbs):
        # a * b_j, shifted by j limbs; only low (L - j) limbs contribute.
        prod = a[..., : num_limbs - j] * b[..., j : j + 1]
        shifted = jnp.concatenate(
            [jnp.zeros(prod.shape[:-1] + (j,), jnp.int32), prod], axis=-1
        )
        acc = acc + shifted
        if (j + 1) % 16 == 0:
            acc = normalize(acc)
    return normalize(acc)


def is_zero(a: jnp.ndarray) -> jnp.ndarray:
    """(..., L) -> (...,) bool."""
    return jnp.all(a == 0, axis=-1)


def widen(a: jnp.ndarray, num_limbs: int) -> jnp.ndarray:
    """Zero-extend (..., L) to (..., num_limbs)."""
    pad = num_limbs - a.shape[-1]
    if pad < 0:
        raise ValueError("cannot narrow")
    if pad == 0:
        return a
    return jnp.concatenate(
        [a, jnp.zeros(a.shape[:-1] + (pad,), jnp.int32)], axis=-1
    )
