"""Sparse multivariate polynomial multiplication as a Stream computation.

The paper's second example (§6): multivariate polynomials in distributive
representation, multiplied by decomposing into a stream of
multiply-by-a-term-and-add operations::

    def times(x: T, y: T) = (zero /: y) { (l, r) => l + multiply(x, a, b) }

Representation (SIMD adaptation, see DESIGN.md §2):

* A polynomial is ``Poly(keys, coeffs)`` with capacity N: ``keys`` int32
  packed exponents (3 vars × 10 bits, graded by integer order — monomial
  product = key add), ``coeffs`` (N, L) limb integers
  (:mod:`repro.algorithms.limb`).  Absent terms have ``key == EMPTY_KEY``
  (int32 max) so sorts push them to the back, and zero coefficients.
* Terms are kept sorted ascending by key; the paper's descending-order
  head/tail traversal maps to our merge direction, which is order-agnostic.
* The paper forces the tail early when a term cancels (`Await.result` —
  "not considered good in a regular use of Futures, but we have not been
  able to avoid it").  In our masked-lane world cancellation just *clears a
  lane* (key := EMPTY) — no blocking; SIMD strictly improves on the wart.

Stream decomposition used here (paper Fig. 2): a genuine **two-source
zip program** in the combinator algebra —

    Stream.source(x_chunks)                       # source 1: chunks of x
          .zip(Stream.source(acc_chunks), ...)    # source 2: accumulators
          .through(y_term_cells, y_state)         # cell j: chunk of y

    item b  = {x-chunk b, partial accumulator b}  (flows)
    cell j  = y-term-chunk j: acc_b += multiply(x_b, m_j, c_j)

Under :class:`FutureEvaluator` both sources are injected through the
generalized feed carousel — each round-robin sharded over the stage
ring, neither replicated per stage.  The accumulator source is not an
artifact: seeding it with chunks of a third polynomial ``z`` computes
the fused multiply-add ``x*y + z`` (:func:`times_into`) in the same
pipeline pass, which is how dot-product-shaped polynomial work avoids
materializing intermediates.

Cells form the dependent `plus` chain the paper pipelines; different items
(x-chunks) are independent, so the Future evaluator overlaps cell j on
chunk b with cell j+1 on chunk b-1.  Final result = tree-add of the M
partial accumulators.

The ``list`` control (paper's parallel-collections baseline [4]) is
:func:`times_dense`: one outer product + sort + segment-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import limb
from repro.core.chunking import chunk_axis
from repro.core.graph import Stream

EMPTY_KEY = np.int32(np.iinfo(np.int32).max)
VAR_BITS = 10
NUM_VARS = 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Poly:
    """Sparse polynomial with fixed capacity; invalid slots key=EMPTY_KEY."""

    keys: jnp.ndarray  # (N,) int32
    coeffs: jnp.ndarray  # (N, L) int32 limbs

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def num_limbs(self) -> int:
        return self.coeffs.shape[-1]


def pack_key(exponents) -> int:
    e = list(exponents) + [0] * (NUM_VARS - len(exponents))
    key = 0
    for x in e:
        assert 0 <= x < (1 << VAR_BITS)
        key = (key << VAR_BITS) | x
    return key


def unpack_key(key: int) -> tuple[int, ...]:
    return tuple(
        (int(key) >> (VAR_BITS * (NUM_VARS - 1 - i))) & ((1 << VAR_BITS) - 1)
        for i in range(NUM_VARS)
    )


def from_dict(terms: dict[tuple[int, ...], int], capacity: int, num_limbs: int) -> Poly:
    """Host-side constructor from {exponent-tuple: int coefficient}."""
    items = sorted((pack_key(e), c) for e, c in terms.items())
    if len(items) > capacity:
        raise ValueError(f"{len(items)} terms exceed capacity {capacity}")
    keys = np.full(capacity, EMPTY_KEY, np.int32)
    coeffs = np.zeros((capacity, num_limbs), np.int32)
    for i, (k, c) in enumerate(items):
        keys[i] = k
        coeffs[i] = np.asarray(limb.from_int(c, num_limbs))
    return Poly(jnp.asarray(keys), jnp.asarray(coeffs))


def to_dict(p: Poly) -> dict[tuple[int, ...], int]:
    """Host-side exact extraction (Python bigints)."""
    keys = np.asarray(p.keys)
    coeffs = np.asarray(p.coeffs)
    out: dict[tuple[int, ...], int] = {}
    for i in range(keys.shape[0]):
        if keys[i] == EMPTY_KEY:
            continue
        value = limb.to_int(coeffs[i])
        if value:
            out[unpack_key(int(keys[i]))] = out.get(unpack_key(int(keys[i])), 0) + value
    return out


# ---------------------------------------------------------------------------
# Core ops (all shape-static, jit-friendly)
# ---------------------------------------------------------------------------


def _mask_invalid(keys: jnp.ndarray, coeffs: jnp.ndarray):
    """Clear lanes whose coefficient is zero (the paper's cancellation)."""
    zero = limb.is_zero(coeffs)
    keys = jnp.where(zero, EMPTY_KEY, keys)
    coeffs = jnp.where(zero[..., None], 0, coeffs)
    return keys, coeffs


def multiply_term(p: Poly, m_key: jnp.ndarray, c_limbs: jnp.ndarray) -> Poly:
    """The paper's ``multiply(x, m, c)``: p * (c * monomial m), vectorized."""
    valid = p.keys != EMPTY_KEY
    keys = jnp.where(valid, p.keys + m_key, EMPTY_KEY)
    coeffs = limb.mul(p.coeffs, c_limbs[None, :])
    keys, coeffs = _mask_invalid(keys, coeffs)
    return Poly(keys, coeffs)


def compact(p: Poly, capacity: int) -> Poly:
    """Sort valid terms to the front; truncate/grow to ``capacity``."""
    order = jnp.argsort(p.keys)
    keys = p.keys[order]
    coeffs = p.coeffs[order]
    n = p.capacity
    if capacity >= n:
        keys = jnp.concatenate([keys, jnp.full((capacity - n,), EMPTY_KEY, jnp.int32)])
        coeffs = jnp.concatenate(
            [coeffs, jnp.zeros((capacity - n, p.num_limbs), jnp.int32)]
        )
    else:
        # Truncation only sound if the tail is empty; callers size capacity.
        keys = keys[:capacity]
        coeffs = coeffs[:capacity]
    return Poly(keys, coeffs)


def plus(x: Poly, y: Poly, capacity: int | None = None) -> Poly:
    """The paper's recursive merge-add, as sort + adjacent-combine.

    Equal keys combine; cancellations clear lanes (no early force).
    """
    capacity = capacity or x.capacity
    keys = jnp.concatenate([x.keys, y.keys])
    coeffs = jnp.concatenate([x.coeffs, y.coeffs])
    order = jnp.argsort(keys)
    keys = keys[order]
    coeffs = coeffs[order]
    # Combine runs of equal keys.  Each input has unique keys, so runs have
    # length <= 2: one adjacent-combine pass suffices.
    same = jnp.concatenate(
        [jnp.zeros((1,), bool), (keys[1:] == keys[:-1]) & (keys[1:] != EMPTY_KEY)]
    )
    shifted = jnp.concatenate([jnp.zeros_like(coeffs[:1]), coeffs[:-1]])
    coeffs = jnp.where(same[:, None], limb.add(coeffs, shifted), coeffs)
    # The first element of each combined pair is dead.
    dead = jnp.concatenate([same[1:], jnp.zeros((1,), bool)])
    keys = jnp.where(dead, EMPTY_KEY, keys)
    coeffs = jnp.where(dead[:, None], 0, coeffs)
    keys, coeffs = _mask_invalid(keys, coeffs)
    return compact(Poly(keys, coeffs), capacity)


def num_terms(p: Poly) -> jnp.ndarray:
    return jnp.sum(p.keys != EMPTY_KEY)


# ---------------------------------------------------------------------------
# times() as a two-source zip Stream
# ---------------------------------------------------------------------------


def _flatten_poly(p: Poly):
    return {"keys": p.keys, "coeffs": p.coeffs}


def _unflatten_poly(d) -> Poly:
    return Poly(d["keys"], d["coeffs"])


def _y_cell_fn(acc_capacity: int):
    """Cell j: acc += x_chunk * (each of my y-term slots)."""

    def cell_fn(cell_state, item):
        x_chunk = _unflatten_poly(item["x"])
        acc = _unflatten_poly(item["acc"])

        def one_term(acc_d, term):
            acc_p = _unflatten_poly(acc_d)
            t_key, t_coeff = term
            prod = multiply_term(x_chunk, t_key, t_coeff)
            # Absent y-term (padding) => multiply_term yields all-EMPTY prod,
            # so the add is a no-op; no control flow needed.
            prod = Poly(
                jnp.where(t_key == EMPTY_KEY, EMPTY_KEY, prod.keys),
                jnp.where(t_key == EMPTY_KEY, 0, prod.coeffs),
            )
            return _flatten_poly(plus(acc_p, prod, acc_capacity)), None

        acc_d, _ = jax.lax.scan(
            one_term,
            _flatten_poly(acc),
            (cell_state["keys"], cell_state["coeffs"]),
        )
        return cell_state, {"x": item["x"], "acc": acc_d}

    return cell_fn


def times_stream(
    x: Poly,
    y: Poly,
    *,
    num_x_chunks: int = 1,
    terms_per_cell: int = 1,
    acc_capacity: int | None = None,
    into: Poly | None = None,
) -> Stream:
    """The product as an algebra program: two sources zipped into a chain.

    Source 1 streams chunks of ``x``; source 2 streams the running
    accumulators — all-EMPTY for a plain product, or chunks of ``into``
    for the fused multiply-add ``x*y + into``.  The zip pairs chunk b
    with accumulator b (source order, deterministic); cell j holds
    y-term chunk j (G = ``terms_per_cell`` is the paper §7 chunk-size
    knob).  Collecting yields M partial accumulators to tree-add.
    """
    acc_capacity = acc_capacity or _product_capacity(x, y)
    if x.capacity % num_x_chunks != 0:
        raise ValueError("x capacity not divisible by num_x_chunks")
    if y.capacity % terms_per_cell != 0:
        raise ValueError("y capacity not divisible by terms_per_cell")
    num_cells = y.capacity // terms_per_cell
    state = {
        "keys": y.keys.reshape(num_cells, terms_per_cell),
        "coeffs": y.coeffs.reshape(num_cells, terms_per_cell, y.num_limbs),
    }
    # Chunking x leaves EMPTY padding distributed arbitrarily; that's fine —
    # multiply_term propagates EMPTY lanes.
    x_items = chunk_axis(_flatten_poly(x), num_x_chunks)
    acc_keys = jnp.full((num_x_chunks, acc_capacity), EMPTY_KEY, jnp.int32)
    acc_coeffs = jnp.zeros(
        (num_x_chunks, acc_capacity, x.num_limbs), jnp.int32
    )
    if into is not None:
        # Seed accumulator chunk 0 with `into` (added exactly once).
        # .at[].set keeps this traceable, so times_into works under jit.
        if into.capacity > acc_capacity:
            raise ValueError(
                f"into capacity {into.capacity} exceeds acc_capacity "
                f"{acc_capacity}"
            )
        acc_keys = acc_keys.at[0, : into.capacity].set(into.keys)
        acc_coeffs = acc_coeffs.at[0, : into.capacity].set(into.coeffs)
    acc_items = {"keys": acc_keys, "coeffs": acc_coeffs}
    return (
        Stream.source(x_items)
        .zip(
            Stream.source(acc_items),
            lambda x_chunk, acc: {"x": x_chunk, "acc": acc},
        )
        .through(
            _y_cell_fn(acc_capacity),
            state,
            num_cells=num_cells,
            mutable_state=False,
        )
    )


def times(
    x: Poly,
    y: Poly,
    *,
    evaluator=None,
    num_x_chunks: int = 1,
    terms_per_cell: int = 1,
    acc_capacity: int | None = None,
) -> Poly:
    """Sparse product x*y via the stream-of-multiply-and-add decomposition.

    ``evaluator=None`` → Lazy (the paper's sequential mode);
    pass a :class:`FutureEvaluator` for the pipelined mode.
    """
    return times_into(
        x,
        y,
        None,
        evaluator=evaluator,
        num_x_chunks=num_x_chunks,
        terms_per_cell=terms_per_cell,
        acc_capacity=acc_capacity,
    )


def times_into(
    x: Poly,
    y: Poly,
    z: Poly | None,
    *,
    evaluator=None,
    num_x_chunks: int = 1,
    terms_per_cell: int = 1,
    acc_capacity: int | None = None,
) -> Poly:
    """Fused multiply-add ``x*y + z`` in one pipeline pass.

    ``z`` rides the accumulator source (zip source 2), so the add costs
    nothing extra — the two-source algebra at work.  ``z=None`` is the
    plain product.
    """
    acc_capacity = acc_capacity or _product_capacity(x, y)
    stream = times_stream(
        x,
        y,
        num_x_chunks=num_x_chunks,
        terms_per_cell=terms_per_cell,
        acc_capacity=acc_capacity,
        into=z,
    )
    out_items = stream.collect(evaluator).items
    partials = [
        Poly(out_items["acc"]["keys"][b], out_items["acc"]["coeffs"][b])
        for b in range(num_x_chunks)
    ]
    acc = partials[0]
    for p in partials[1:]:
        acc = plus(acc, p, acc_capacity)
    return acc


def _product_capacity(x: Poly, y: Poly) -> int:
    cap = x.capacity * y.capacity
    return int(min(cap, 1 << 15))


# ---------------------------------------------------------------------------
# The "list" control: data-parallel outer product (paper's baseline [4])
# ---------------------------------------------------------------------------


def times_dense(x: Poly, y: Poly, capacity: int | None = None) -> Poly:
    """Parallel-collections analogue: all |x|·|y| term products at once.

    Outer product of keys/coeffs, then a single sort + segmented combine.
    This is the classical well-optimized baseline the paper compares
    against (its ``list`` rows).
    """
    capacity = capacity or _product_capacity(x, y)
    kx, ky = x.keys, y.keys
    valid = (kx[:, None] != EMPTY_KEY) & (ky[None, :] != EMPTY_KEY)
    keys = jnp.where(valid, kx[:, None] + ky[None, :], EMPTY_KEY).reshape(-1)
    coeffs = limb.mul(x.coeffs[:, None, :], y.coeffs[None, :, :]).reshape(
        -1, x.num_limbs
    )
    coeffs = jnp.where(valid.reshape(-1, 1), coeffs, 0)
    order = jnp.argsort(keys)
    keys = keys[order]
    coeffs = coeffs[order]
    # Segmented reduce of equal-key runs (runs can be long): log-step
    # prefix-combine on sorted keys.
    n = keys.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(n, 2)))))
    seg_sum = coeffs
    for shift in [1 << s for s in range(steps)]:
        prev_key = jnp.concatenate([jnp.full((shift,), -1, jnp.int32), keys[:-shift]])
        prev_sum = jnp.concatenate([jnp.zeros_like(seg_sum[:shift]), seg_sum[:-shift]])
        take = prev_key == keys
        seg_sum = jnp.where(take[:, None], limb.add(seg_sum, prev_sum), seg_sum)
    # Keep only the last element of each run (holds the full segment sum).
    next_key = jnp.concatenate([keys[1:], jnp.full((1,), -1, jnp.int32)])
    last = keys != next_key
    keys = jnp.where(last & (keys != EMPTY_KEY), keys, EMPTY_KEY)
    coeffs = jnp.where((keys != EMPTY_KEY)[:, None], seg_sum, 0)
    keys, coeffs = _mask_invalid(keys, coeffs)
    return compact(Poly(keys, coeffs), capacity)


# ---------------------------------------------------------------------------
# Test-case generator (Fateman benchmark, as cited by the paper [2])
# ---------------------------------------------------------------------------


def fateman_poly(power: int, capacity: int, num_limbs: int, big_factor: int = 1) -> Poly:
    """(1 + x + y + z)^power, coefficients optionally scaled by big_factor.

    ``big_factor=100000000001`` reproduces the paper's ``stream_big``.
    Built host-side with exact Python ints.
    """
    terms: dict[tuple[int, ...], int] = {(0, 0, 0): 1}
    for _ in range(power):
        new: dict[tuple[int, ...], int] = {}
        for (a, b, c), coef in terms.items():
            for d in ((0, 0, 0), (1, 0, 0), (0, 1, 0), (0, 0, 1)):
                key = (a + d[0], b + d[1], c + d[2])
                new[key] = new.get(key, 0) + coef
        terms = new
    if big_factor != 1:
        terms = {k: v * big_factor for k, v in terms.items()}
    return from_dict(terms, capacity, num_limbs)


def reference_product(
    x_terms: dict[tuple[int, ...], int], y_terms: dict[tuple[int, ...], int]
) -> dict[tuple[int, ...], int]:
    """Exact oracle with Python bigints."""
    out: dict[tuple[int, ...], int] = {}
    for ex, cx in x_terms.items():
        for ey, cy in y_terms.items():
            key = tuple(a + b for a, b in zip(ex, ey))
            out[key] = out.get(key, 0) + cx * cy
    return {k: v for k, v in out.items() if v}
