"""The paper's prime sieve (§5) as a Stream computation.

Original (deliberately naive — "it scans every divisor of a number up to
the number itself", the paper keeps it because it is *parallelizable*)::

    def sieve(s: Stream[Int]): Stream[Int] =
      s match { case head#::tail =>
        head#::tail.map(s => sieve(s.filter { _ % head != 0 })) }

i.e. a growing chain of filter cells, one per prime found.  SIMD
adaptation: candidates flow through the chain in *blocks* (bounded stream,
as the paper's own Future version: ``Stream.range(2, n, 1)``); each cell
owns up to ``primes_per_cell`` primes (the §7 chunk-size knob — K=1 is the
paper's original fine-grained cell).  A cell filters the incoming block by
its primes and claims new primes from the surviving front of the block if
it still has free slots.

Under :class:`LazyEvaluator` this is the paper's sequential sieve; under
:class:`FutureEvaluator` block b is filtered by cell j while cell j+1
filters block b-1 — the pipeline of Figure 1.

In the combinator algebra the sieve is the canonical ``mask`` program:
the candidate stream is bounded (``Stream.range``-style blocks padded to
a rectangle), so validity is data —

    Stream.source(blocks).mask(lambda v: v < limit)
          .through(sieve_cell, primes_state)

``mask`` tags each block with ``{"value", "valid"}``; the filter cells
then *narrow* the mask as composites are eliminated (the paper's
``filter { _ % head != 0 }``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from jax import lax

from repro.core.graph import Stream


def sieve_cell(state, item):
    """One chain cell: state = claimed primes (K,), 0 = free slot.

    ``item`` is a masked block ``{"value": (B,), "valid": (B,)}`` as
    produced by ``Stream.mask``; surviving candidates keep their valid
    bit, eliminated composites lose it.
    """
    primes = state  # (K,)
    values, valid = item["value"], item["valid"]

    def slot(carry, p):
        values, valid = carry
        # If this slot already holds a prime, filter by it; otherwise
        # claim the first survivor (which is prime: it survived every
        # earlier prime's filter) and filter by it.
        has_any = jnp.any(valid)
        first = jnp.argmax(valid)
        candidate = values[first]
        new_p = jnp.where((p == 0) & has_any, candidate, p)
        keep = jnp.where(
            new_p > 0,
            valid & (values % jnp.maximum(new_p, 1) != 0),
            valid,
        )
        return (values, keep), new_p

    (values, valid), new_primes = lax.scan(slot, (values, valid), primes)
    return new_primes, {"value": values, "valid": valid}


def sieve_stream(
    limit: int,
    *,
    block_size: int = 256,
    primes_per_cell: int = 1,
    num_cells: int | None = None,
) -> Stream:
    """The sieve as an algebra program: ``source . mask . through``."""
    if num_cells is None:
        # Upper bound on pi(limit): enough cell slots to hold every prime.
        bound = int(_pi_upper_bound(limit))
        num_cells = -(-bound // primes_per_cell)
    n = limit - 2
    num_blocks = -(-n // block_size)
    values = np.arange(2, 2 + num_blocks * block_size, dtype=np.int32)
    blocks = jnp.asarray(values.reshape(num_blocks, block_size))
    init = jnp.zeros((num_cells, primes_per_cell), jnp.int32)
    return (
        Stream.source(blocks)
        .mask(lambda v: v < limit)
        .through(sieve_cell, init, num_cells=num_cells)
    )


def run_sieve(
    limit: int,
    *,
    block_size: int = 256,
    primes_per_cell: int = 1,
    num_cells: int | None = None,
    evaluator=None,
):
    """All primes < ``limit``.  Returns (primes int32[num_slots], count)."""
    stream = sieve_stream(
        limit,
        block_size=block_size,
        primes_per_cell=primes_per_cell,
        num_cells=num_cells,
    )
    result = stream.collect(evaluator)
    primes = result.states[0].reshape(-1)
    count = jnp.sum(primes > 0)
    return primes, count


def _pi_upper_bound(limit: int) -> float:
    """pi(x) < 1.3 x / ln x for x >= 17 (Rosser–Schoenfeld)."""
    if limit < 17:
        return 8
    return 1.3 * limit / np.log(limit)


def reference_primes(limit: int) -> np.ndarray:
    """Classic Eratosthenes oracle (numpy, host)."""
    mask = np.ones(limit, bool)
    mask[:2] = False
    for p in range(2, int(limit**0.5) + 1):
        if mask[p]:
            mask[p * p :: p] = False
    return np.nonzero(mask)[0].astype(np.int32)
