"""End-to-end LM training example: trains a reduced-config model on the
synthetic corpus for a few hundred steps with checkpointing and fault
tolerance, and verifies the loss decreases.

Run (a ~25M-param model, a few minutes on CPU):
    PYTHONPATH=src python examples/train_lm.py

A larger (~100M) run, as the assignment's end-to-end driver:
    PYTHONPATH=src python examples/train_lm.py --big
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--pipeline-schedule", default="one_f_one_b",
                    choices=["gpipe", "one_f_one_b", "interleaved"])
    ap.add_argument("--pipeline-backward", default="planned",
                    choices=["autodiff", "planned"],
                    help="true-1F1B custom-VJP backward (planned) or the "
                         "jax.grad transpose of the forward plan")
    args = ap.parse_args()

    if args.big:
        argv = [
            "--arch", "olmo-1b", "--smoke", "--d-model", "640", "--layers", "16",
            "--steps", str(args.steps or 300), "--global-batch", "8",
            "--seq-len", "512", "--microbatches", "2",
        ]
    else:
        argv = [
            "--arch", "olmo-1b", "--smoke", "--d-model", "320", "--layers", "8",
            "--steps", str(args.steps or 200), "--global-batch", "8",
            "--seq-len", "256", "--microbatches", "2",
        ]
    argv += [
        "--pipeline-schedule", args.pipeline_schedule,
        "--pipeline-backward", args.pipeline_backward,
    ]
    history = train_main(argv)
    first = sum(h["loss"] for h in history[:10]) / 10
    last = sum(h["loss"] for h in history[-10:]) / 10
    print(f"mean loss first-10 {first:.4f} -> last-10 {last:.4f}")
    assert last < first, "loss did not decrease"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
