"""Quickstart: Stream with a substitutable evaluation monad.

Builds a tiny stream program, runs it under the Lazy monad (sequential)
and — if more than one JAX device is available — under the Future monad
(pipelined across devices), demonstrating the paper's monad substitution:
the program text does not change, only the evaluator.

Run:
    PYTHONPATH=src python examples/quickstart.py
    # pipelined across 4 virtual devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import (
    FutureEvaluator,
    LazyEvaluator,
    StreamProgram,
    bubble_fraction,
    evaluate,
    optimal_num_chunks,
)
from repro.algorithms import sieve


def main():
    # --- 1. A stream of dependent cells -----------------------------------
    # Cell s multiplies the flowing item by a per-cell weight and bumps a
    # per-cell counter (mutable state, like the sieve's claimed primes).
    def cell_fn(state, item):
        weight, count = state
        return (weight, count + 1), jnp.tanh(item * weight)

    num_cells, num_items = 8, 16
    states = (jnp.linspace(0.5, 1.5, num_cells), jnp.zeros(num_cells, jnp.int32))
    items = jnp.linspace(-1.0, 1.0, num_items * 4).reshape(num_items, 4)
    program = StreamProgram(cell_fn, states, num_cells)

    (_, counts), outs = evaluate(program, items, LazyEvaluator())
    print("lazy:   outs[0] =", np.asarray(outs[0]))

    if jax.device_count() >= 2 and num_cells % jax.device_count() == 0:
        mesh = compat.make_mesh(
            (jax.device_count(),), ("pod",),
            axis_types=(compat.AxisType.Auto,),
        )
        (_, counts_f), outs_f = evaluate(
            program, items, FutureEvaluator(mesh, "pod")
        )
        print("future: outs[0] =", np.asarray(outs_f[0]))
        print("lazy == future:", bool(jnp.allclose(outs, outs_f)))
        print(
            f"bubble fraction (S={jax.device_count()}, M={num_items}):",
            bubble_fraction(jax.device_count(), num_items),
        )
    else:
        print("(single device: set XLA_FLAGS=--xla_force_host_platform_"
              "device_count=4 to see the Future evaluator)")

    # --- 2. The paper's §7 chunking rule -----------------------------------
    print(
        "optimal #chunks for work=1s, 4 stages, 1ms overhead:",
        optimal_num_chunks(1.0, 4, 1e-3),
    )

    # --- 3. The paper's prime sieve (§5) ------------------------------------
    primes, count = sieve.run_sieve(200, block_size=64, primes_per_cell=4)
    primes = np.asarray(primes)
    print(f"primes < 200 ({int(count)}):", primes[primes > 0])


if __name__ == "__main__":
    main()
