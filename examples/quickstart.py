"""Quickstart: the Stream combinator algebra with a substitutable monad.

Builds stream programs with the algebra — ``source . map . through .
zip . collect`` — and runs them under the Lazy monad (sequential) and,
if more than one JAX device is available, under the Future monad
(pipelined across devices), demonstrating the paper's monad
substitution: the program text does not change, only the evaluator.

Run:
    PYTHONPATH=src python examples/quickstart.py
    # pipelined across 4 virtual devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import (
    FutureEvaluator,
    LazyEvaluator,
    Stream,
    bubble_fraction,
    optimal_num_chunks,
)
from repro.algorithms import sieve


def main():
    # --- 1. A stream program, written with combinators ---------------------
    # Cell s multiplies the flowing item by a per-cell weight and bumps a
    # per-cell counter (mutable state, like the sieve's claimed primes).
    def cell_fn(state, item):
        weight, count = state
        return (weight, count + 1), jnp.tanh(item * weight)

    num_cells, num_items = 8, 16
    states = (jnp.linspace(0.5, 1.5, num_cells), jnp.zeros(num_cells, jnp.int32))
    items = jnp.linspace(-1.0, 1.0, num_items * 4).reshape(num_items, 4)

    program = (
        Stream.source(items)
        .map(lambda x: x * 2.0)          # stateless: fused at lowering
        .through(cell_fn, states)        # the chain of dependent cells
    )

    lazy = program.collect(LazyEvaluator())
    print("lazy:   outs[0] =", np.asarray(lazy.items[0]))

    if jax.device_count() >= 2 and num_cells % jax.device_count() == 0:
        mesh = compat.make_mesh(
            (jax.device_count(),), ("pod",),
            axis_types=(compat.AxisType.Auto,),
        )
        fut = program.collect(FutureEvaluator(mesh, "pod"))
        print("future: outs[0] =", np.asarray(fut.items[0]))
        print("lazy == future:", bool(jnp.all(lazy.items == fut.items)))
        print(
            f"bubble fraction (S={jax.device_count()}, M={num_items}):",
            bubble_fraction(jax.device_count(), num_items),
        )

        # --- 1b. Multi-source: zip a second stream in ----------------------
        # Each source gets its own feed carousel; neither is replicated.
        other = jnp.linspace(0.0, 1.0, num_items * 4).reshape(num_items, 4)
        zipped = (
            Stream.source(items)
            .zip(Stream.source(other), lambda a, b: a + 0.25 * b)
            .through(cell_fn, states)
        )
        zl = zipped.collect(LazyEvaluator())
        zf = zipped.collect(FutureEvaluator(mesh, "pod"))
        print("zip: lazy == future:", bool(jnp.all(zl.items == zf.items)))
    else:
        print("(single device: set XLA_FLAGS=--xla_force_host_platform_"
              "device_count=4 to see the Future evaluator)")

    # --- 1c. Feedback: a self-feeding stream (the serving decode shape) ----
    # Item b re-enters as emit(item b - lag): this is a decode loop —
    # the emitted token is the next step's input, per-cell state is the
    # KV cache, and `lag` in-flight items keep a pipeline busy.
    lag = 4
    fb = (
        Stream.feedback(items[:lag], num_items=12, emit=lambda x: x * 0.5 + 0.1)
        .through(cell_fn, states)
    )
    fb_lazy = fb.collect(LazyEvaluator())
    print("feedback: outs[-1] =", np.asarray(fb_lazy.items[-1]))

    # --- 2. The paper's §7 chunking rule -----------------------------------
    print(
        "optimal #chunks for work=1s, 4 stages, 1ms overhead:",
        optimal_num_chunks(1.0, 4, 1e-3),
    )

    # --- 3. The paper's prime sieve (§5): source . mask . through ----------
    primes, count = sieve.run_sieve(200, block_size=64, primes_per_cell=4)
    primes = np.asarray(primes)
    print(f"primes < 200 ({int(count)}):", primes[primes > 0])

    # --- 4. Stream-shaped serving: decode as a feedback program ------------
    # The serving engine is the same construct at production scale: the
    # transformer's layer groups are the cells (each owning its KV-cache
    # shard as per-cell state), in-flight request microbatches are the
    # items, and the emit (logits -> sample -> re-embed) closes the
    # loop.  StreamEngine runs it under LazyEvaluator here; give it a
    # mesh and it pipelines across devices (gpipe / interleaved),
    # bit-identically.
    from repro.configs.base import DecodePipelineConfig
    from repro.configs.registry import get_config, smoke_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serve.engine import ServeConfig, StreamEngine

    cfg = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=4)
    params = init_params(jax.random.PRNGKey(0), T.model_layout(cfg))
    eng = StreamEngine(
        params, cfg,
        ServeConfig(max_batch=4, max_len=64, prefill_chunk=8, max_new_tokens=6),
        DecodePipelineConfig(num_cells=4, microbatches=2, round_steps=4),
        mesh=None,  # pass a 1-axis mesh to pipeline the cells across it
    )
    reqs = [eng.submit(np.array([5, 9, 2, 7])), eng.submit(np.array([3, 1]))]
    eng.run_until_drained()
    for r in reqs:
        print(f"served req {r.uid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
