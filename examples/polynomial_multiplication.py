"""Paper §6: sparse polynomial multiplication as a stream computation.

Reproduces the paper's experiment shape: ``stream`` (small coefficients)
vs ``stream_big`` (coefficients × 100000000001) under the Lazy and Future
evaluators, plus the data-parallel ``list`` control.

Run (2 virtual devices ≈ the paper's hyperthreaded Atom):
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python examples/polynomial_multiplication.py --power 6
"""
import argparse
import time

import jax
import numpy as np

from repro import compat
from repro.algorithms import polynomial as poly
from repro.core import FutureEvaluator


def timed(fn, *args, repeats=1, **kwargs):
    out = fn(*args, **kwargs)  # compile
    jax.block_until_ready(out.coeffs)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out.coeffs)
    return out, (time.perf_counter() - t0) / repeats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--power", type=int, default=6, help="k in (1+x+y+z)^k")
    ap.add_argument("--terms-per-cell", type=int, default=8)
    ap.add_argument("--x-chunks", type=int, default=4)
    args = ap.parse_args()

    n_terms = (args.power + 3) * (args.power + 2) * (args.power + 1) // 6
    # capacity must be divisible by terms_per_cell × device_count (cells)
    # and by x_chunks (items).
    quantum = args.terms_per_cell * max(jax.device_count(), args.x_chunks)
    cap = -(-n_terms // quantum) * quantum
    p2 = args.power * 2
    acc_cap = 1 << ((p2 + 3) * (p2 + 2) * (p2 + 1) // 6 - 1).bit_length()
    print(f"(1+x+y+z)^{args.power}: {n_terms} terms (cap {cap}) -> product capacity {acc_cap}")

    for tag, limbs, big in (("stream", 4, 1), ("stream_big", 12, 100000000001)):
        x = poly.fateman_poly(args.power, cap, limbs, big_factor=big)
        y = poly.fateman_poly(args.power, cap, limbs, big_factor=big)
        ref = poly.reference_product(poly.to_dict(x), poly.to_dict(y))

        jit_times = jax.jit(
            lambda x, y: poly.times(
                x, y,
                num_x_chunks=args.x_chunks,
                terms_per_cell=args.terms_per_cell,
                acc_capacity=acc_cap,
            )
        )
        out, seq = timed(jit_times, x, y)
        assert poly.to_dict(out) == ref, "stream/lazy result mismatch"

        if jax.device_count() >= 2:
            mesh = compat.make_mesh(
                (jax.device_count(),), ("pod",),
                axis_types=(compat.AxisType.Auto,),
            )
            fut = FutureEvaluator(mesh, "pod")
            jit_par = jax.jit(
                lambda x, y: poly.times(
                    x, y, evaluator=fut,
                    num_x_chunks=args.x_chunks,
                    terms_per_cell=args.terms_per_cell,
                    acc_capacity=acc_cap,
                )
            )
            outp, par = timed(jit_par, x, y)
            assert poly.to_dict(outp) == ref, "stream/future result mismatch"
        else:
            par = float("nan")

        jit_dense = jax.jit(lambda x, y: poly.times_dense(x, y, capacity=acc_cap))
        outd, dense = timed(jit_dense, x, y)
        assert poly.to_dict(outd) == ref, "list result mismatch"

        print(
            f"{tag:12s} seq(Lazy) {seq*1e3:8.1f} ms   "
            f"par(Future,{jax.device_count()}dev) {par*1e3:8.1f} ms   "
            f"list(dense) {dense*1e3:8.1f} ms"
        )


if __name__ == "__main__":
    main()
