"""End-to-end serving example: continuous batching with chunked prefill
on a reduced qwen3 config; prints throughput.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main([
        "--arch", "qwen3-32b", "--smoke", "--requests", "12",
        "--max-batch", "4", "--max-new", "8", "--prompt-len", "20",
    ])
