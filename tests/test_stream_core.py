"""Core Stream/Future construct: semantics, chunking math, combinators."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Future,
    FutureEvaluator,
    LazyEvaluator,
    Stream,
    StreamProgram,
    bubble_fraction,
    build_backward_plan,
    build_combined_plan,
    build_plan,
    chunk_axis,
    defer,
    evaluate,
    feed_peak_items,
    optimal_num_chunks,
    optimal_schedule,
    pipeline_step_time,
    schedule_bubble_fraction,
    schedule_peak_items,
    schedule_ticks,
    unchunk_axis,
)
from repro.core.future import HostFuture
from repro.core.schedules import UNIT_B, UNIT_F, UNIT_W


def _counting_program(num_cells):
    def cell(state, item):
        return state + 1, item * 1.5 + state.astype(jnp.float32)

    return StreamProgram(cell, jnp.arange(num_cells, dtype=jnp.int32), num_cells)


class TestLazyEvaluator:
    def test_matches_python_reference(self):
        prog = _counting_program(3)
        items = jnp.asarray([[1.0], [2.0]])
        states, outs = evaluate(prog, items, LazyEvaluator())
        # python reference with the same ordering semantics
        st_ref = np.arange(3, dtype=np.int64)
        outs_ref = []
        for it in [1.0, 2.0]:
            flow = it
            for s in range(3):
                flow = flow * 1.5 + st_ref[s]
                st_ref[s] += 1
            outs_ref.append(flow)
        np.testing.assert_array_equal(np.asarray(states), st_ref)
        np.testing.assert_allclose(np.asarray(outs)[:, 0], outs_ref, rtol=1e-6)

    def test_state_mutation_order(self):
        # each cell counts items seen: all cells see all items
        prog = _counting_program(4)
        items = jnp.ones((5, 1))
        states, _ = evaluate(prog, items)
        np.testing.assert_array_equal(
            np.asarray(states), np.arange(4) + 5
        )

    def test_immutable_state(self):
        def cell(w, x):
            return w + 1, x * w

        prog = StreamProgram(cell, jnp.ones(2), 2, mutable_state=False)
        states, outs = evaluate(prog, jnp.ones((3, 1)))
        np.testing.assert_array_equal(np.asarray(states), np.ones(2))

    def test_bad_state_shape_raises(self):
        with pytest.raises(ValueError):
            StreamProgram(lambda s, x: (s, x), jnp.zeros((3,)), 4)


class TestChunking:
    @hypothesis.given(
        st.integers(min_value=1, max_value=64),
        st.integers(min_value=1, max_value=64),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_bubble_fraction_bounds(self, s, m):
        frac = bubble_fraction(s, m)
        assert 0.0 <= frac < 1.0
        if s == 1:
            assert frac == 0.0

    @hypothesis.given(
        st.floats(min_value=1e-3, max_value=10.0),
        st.integers(min_value=2, max_value=32),
        st.floats(min_value=1e-6, max_value=1e-1),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_optimal_chunks_is_argmin(self, work, stages, overhead):
        m_star = optimal_num_chunks(work, stages, overhead)
        t_star = pipeline_step_time(work, stages, m_star, overhead)
        for m in {max(1, m_star // 2), m_star * 2, 1, 4096}:
            assert t_star <= pipeline_step_time(work, stages, m, overhead) * 1.0001

    def test_paper_primes_regime(self):
        # fine-grained cells (overhead >> work/cell): don't pipeline
        assert optimal_num_chunks(1e-4, 8, 1e-2) == 1

    def test_chunk_roundtrip(self):
        tree = {"a": jnp.arange(24).reshape(12, 2), "b": jnp.arange(12)}
        again = unchunk_axis(chunk_axis(tree, 4))
        for k in tree:
            np.testing.assert_array_equal(np.asarray(tree[k]), np.asarray(again[k]))

    def test_chunk_indivisible_raises(self):
        with pytest.raises(ValueError):
            chunk_axis(jnp.arange(10), 3)


class TestCopyBytesTerm:
    """The per-tick state-copy term (the serving cache-traffic model)."""

    def test_step_time_additive_per_tick(self):
        base = pipeline_step_time(1.0, 4, 8, 1e-3)
        with_copy = pipeline_step_time(1.0, 4, 8, 1e-3, per_tick_copy=2e-3)
        ticks = schedule_ticks("gpipe", 4, 8, handoff=1)
        assert with_copy == pytest.approx(base + ticks * 2e-3)

    def test_copy_pushes_chunks_down(self):
        # a fixed per-tick copy behaves like overhead in the M* closed
        # form: heavy write-back => fewer, bigger chunks
        light = optimal_num_chunks(1.0, 4, 1e-4)
        heavy = optimal_num_chunks(1.0, 4, 1e-4, per_tick_copy=1e-2)
        assert heavy < light

    def test_copy_term_reaches_joint_pick(self):
        # interleaving multiplies tick count; a big per-tick copy must be
        # able to flip the winner away from the high-V schedule
        free = optimal_schedule(1.0, 4, 1e-4, interleave_options=(1, 4))
        taxed = optimal_schedule(
            1.0, 4, 1e-4, interleave_options=(1, 4), per_tick_copy=5e-2
        )
        assert free.modeled_time < taxed.modeled_time
        assert taxed.num_chunks <= free.num_chunks

    def test_copy_time_conversion_validates(self):
        from repro.core.chunking import copy_time_per_tick

        assert copy_time_per_tick(1e9, 50e9) == pytest.approx(0.02)
        with pytest.raises(ValueError, match="copy_bytes_per_second"):
            copy_time_per_tick(1.0, 0.0)

    def test_decode_row_bytes_are_maxlen_smaller_than_slab(self):
        from repro.configs.registry import get_config, smoke_config
        from repro.serve.engine import decode_copy_bytes_per_tick

        cfg = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=8)
        rows = decode_copy_bytes_per_tick(cfg, 4, 8)
        slab = decode_copy_bytes_per_tick(
            cfg, 4, 8, row_scatter=False, max_len=256
        )
        assert rows > 0
        # attention K/V dominates this config: the slab term is the row
        # term scaled by max_len
        assert slab == rows * 256

    def test_suggest_decode_pipeline_threads_the_term(self):
        from repro.configs.registry import get_config, smoke_config
        from repro.serve.engine import suggest_decode_pipeline

        cfg = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=8)
        row_pick = suggest_decode_pipeline(
            cfg, devices=4, work_per_item=1e-3, per_tick_overhead=1e-7,
            microbatch=4, num_cells=8, copy_bytes_per_second=1e9,
        )
        slab_pick = suggest_decode_pipeline(
            cfg, devices=4, work_per_item=1e-3, per_tick_overhead=1e-7,
            microbatch=4, num_cells=8, copy_bytes_per_second=1e9,
            row_scatter=False,
        )
        # the slab scheme's max_len-times-larger traffic shows up as a
        # slower modeled step and (generally) fewer chunks
        assert slab_pick.modeled_time > row_pick.modeled_time


class TestSchedulePlans:
    """The analytic chunking model must match the tick tables the
    schedules actually emit — modeled bubble == measured bubble."""

    GRID = [
        (name, d, m, v)
        for name in ("gpipe", "one_f_one_b")
        for d in (1, 2, 3, 4, 8)
        for m in (1, 2, 4, 5, 8, 16)
        for v in (1,)
    ] + [
        ("interleaved", d, m, v)
        for d in (2, 3, 4)
        for m in (1, 2, 4, 5, 8, 16)
        for v in (2, 3, 4)
    ]

    def test_model_ticks_match_plans(self):
        for name, d, m, v in self.GRID:
            plan = build_plan(name, d, m, v)
            assert plan.num_ticks == schedule_ticks(
                name, d, m, v, handoff=plan.handoff
            ), (name, d, m, v)

    def test_model_bubble_matches_plans(self):
        for name, d, m, v in self.GRID:
            plan = build_plan(name, d, m, v)
            modeled = schedule_bubble_fraction(name, d, m, v, handoff=plan.handoff)
            assert abs(plan.bubble_fraction - modeled) < 1e-9, (name, d, m, v)

    def test_interleaving_shrinks_bubble(self):
        g = build_plan("gpipe", 4, 8)
        i2 = build_plan("interleaved", 4, 8, 2)
        i4 = build_plan("interleaved", 4, 8, 4)
        assert i4.bubble_fraction < i2.bubble_fraction < g.bubble_fraction

    def test_every_unit_scheduled_once(self):
        for name, d, m, v in [("gpipe", 4, 8, 1), ("interleaved", 4, 8, 2)]:
            plan = build_plan(name, d, m, v)
            seen = set()
            for t in range(plan.num_ticks):
                for dev in range(d):
                    mb = plan.microbatch[t, dev]
                    if mb >= 0:
                        unit = (int(plan.group[t, dev]) * d + dev, int(mb))
                        assert unit not in seen
                        seen.add(unit)
            assert len(seen) == d * v * m

    def test_collection_only_on_last_stage(self):
        for name, d, m, v in [("gpipe", 4, 8, 1), ("interleaved", 4, 8, 2)]:
            plan = build_plan(name, d, m, v)
            assert plan.collect[:, : d - 1].sum() == 0
            assert plan.collect[:, d - 1].sum() == m

    def test_emit_column_zero_without_feedback(self):
        for name, d, m, v in [("gpipe", 4, 8, 1), ("interleaved", 4, 8, 2)]:
            plan = build_plan(name, d, m, v)
            assert plan.emit.sum() == 0

    def test_emit_column_is_last_stage_only_under_feedback(self):
        """The plan-level half of the emit split: emit placement equals
        collect (every final-position unit emits, once per item) and is
        confined to the final-stage device — the contract the evaluator's
        sole head region keys off."""
        for name, d, m, v, lag in [
            ("gpipe", 4, 16, 1, 8),
            ("gpipe", 4, 16, 1, 4),
            ("one_f_one_b", 4, 16, 1, 8),
            ("interleaved", 4, 16, 2, 8),
            ("gpipe", 2, 8, 1, 2),
        ]:
            plan = build_plan(name, d, m, v, feedback_lag=lag)
            assert (plan.emit == plan.collect).all(), (name, d, m, v, lag)
            assert plan.emit[:, : d - 1].sum() == 0, (name, d, m, v, lag)
            assert plan.emit[:, d - 1].sum() == m, (name, d, m, v, lag)

    def test_peak_items_ordering(self):
        # 1F1B's whole point: stash min(S, M) microbatches, not M
        assert schedule_peak_items("one_f_one_b", 4, 16) == 4
        assert schedule_peak_items("gpipe", 4, 16) == 16

    def test_optimal_schedule_joint_pick(self):
        # bubble-dominated regime: interleaving wins
        choice = optimal_schedule(1.0, 8, 1e-6, max_chunks=64)
        assert choice.schedule == "interleaved"
        # overhead-dominated: plain schedules, tiny M (paper's primes case)
        choice = optimal_schedule(1e-4, 8, 1e-2, max_chunks=64)
        assert choice.interleave == 1 and choice.num_chunks == 1
        # memory budget forces off gpipe (gpipe peak is always 1.0
        # items) — a planned-backward job, where schedules' stash
        # bounds are real and a sub-1.0 budget is satisfiable at all
        choice = optimal_schedule(
            1.0, 8, 1e-4, max_chunks=256, memory_budget_items=0.5,
            backward="planned",
        )
        assert choice.schedule != "gpipe"
        assert (
            schedule_peak_items(
                choice.schedule, 8, choice.num_chunks, choice.interleave
            )
            / choice.num_chunks
            <= 0.5
        )

    def test_schedule_validation(self):
        with pytest.raises(ValueError):
            build_plan("zigzag", 4, 8)
        with pytest.raises(ValueError):
            build_plan("gpipe", 4, 8, interleave=2)


class TestMultiInjectionPlans:
    """The generalized feed carousel: per-source columns for multi-source
    streams injecting at arbitrary virtual-stage boundaries."""

    GRID = [
        ("gpipe", 4, 8, 1, (0, 2)),
        ("gpipe", 4, 5, 1, (0, 0, 3)),
        ("one_f_one_b", 4, 8, 1, (0, 1)),
        ("interleaved", 4, 8, 2, (0, 5)),
        ("interleaved", 2, 6, 3, (0, 4)),
    ]

    def test_injections_never_change_the_makespan(self):
        for name, d, m, v, pos in self.GRID:
            plain = build_plan(name, d, m, v)
            multi = build_plan(name, d, m, v, inject_positions=pos)
            assert multi.num_ticks == plain.num_ticks, (name, d, m, v, pos)
            np.testing.assert_array_equal(multi.microbatch, plain.microbatch)

    def test_each_source_consumed_exactly_m_times(self):
        for name, d, m, v, pos in self.GRID:
            plan = build_plan(name, d, m, v, inject_positions=pos)
            assert plan.num_sources == len(pos)
            np.testing.assert_array_equal(
                plan.src_consume.sum(axis=1), [m] * len(pos)
            )

    def test_reload_every_dth_consumption(self):
        for name, d, m, v, pos in self.GRID:
            plan = build_plan(name, d, m, v, inject_positions=pos)
            for s in range(len(pos)):
                # reloads happen on consumptions 0, D, 2D, ...
                assert plan.src_feed_reload[s].sum() == -(-m // d)
                ticks = np.nonzero(plan.src_feed_reload[s])[0]
                np.testing.assert_array_equal(
                    plan.src_feed_idx[s][ticks], np.arange(len(ticks))
                )

    def test_inject_devices_follow_positions(self):
        plan = build_plan("interleaved", 4, 8, 2, inject_positions=(0, 5))
        assert plan.inject_devices == (0, 1)  # virtual stage 5 on device 1

    def test_legacy_columns_alias_source_zero(self):
        plan = build_plan("gpipe", 4, 8, inject_positions=(0, 2))
        np.testing.assert_array_equal(plan.feed_reload, plan.src_feed_reload[0])
        np.testing.assert_array_equal(plan.feed_idx, plan.src_feed_idx[0])
        np.testing.assert_array_equal(plan.feed_advance, plan.src_feed_advance[0])
        np.testing.assert_array_equal(plan.inject, plan.src_consume[0])

    def test_position_validation(self):
        with pytest.raises(ValueError, match="chain entry"):
            build_plan("gpipe", 4, 8, inject_positions=(1,))
        with pytest.raises(ValueError, match="outside"):
            build_plan("gpipe", 4, 8, inject_positions=(0, 4))
        with pytest.raises(ValueError, match="outside"):
            build_plan("interleaved", 4, 8, 2, inject_positions=(0, 8))

    def test_plan_peak_charges_its_own_sources(self):
        # the plan's self-reported peak must use the same multi-source
        # model optimal_schedule budgets against
        single = build_plan("gpipe", 4, 8)
        multi = build_plan("gpipe", 4, 8, inject_positions=(0, 2))
        assert single.peak_inflight_items == 8
        assert multi.peak_inflight_items == schedule_peak_items(
            "gpipe", 4, 8, num_sources=2
        )
        assert multi.peak_inflight_items > single.peak_inflight_items

    def test_feed_memory_terms(self):
        # one source: shard + register; each extra source adds the same
        assert feed_peak_items(4, 8, 1) == 3
        assert feed_peak_items(4, 8, 2) == 6
        assert feed_peak_items(4, 5, 2) == 2 * (2 + 1)
        with pytest.raises(ValueError):
            feed_peak_items(4, 8, 0)
        # schedule peak charges extra sources' feeds, primary grandfathered
        base = schedule_peak_items("one_f_one_b", 4, 16)
        multi = schedule_peak_items("one_f_one_b", 4, 16, num_sources=3)
        assert multi == base + 2 * (4 + 1)

    def test_multi_source_budget_shifts_choice(self):
        # same regime, but feed storage charged against the budget: more
        # sources must never *relax* the constraint
        one = optimal_schedule(
            1.0, 8, 1e-4, max_chunks=256, memory_budget_items=0.6,
            backward="planned",
        )
        many = optimal_schedule(
            1.0, 8, 1e-4, max_chunks=256, memory_budget_items=0.6,
            num_sources=4, backward="planned",
        )
        assert many.peak_items >= one.peak_items
        assert (
            schedule_peak_items(
                many.schedule, 8, many.num_chunks, many.interleave, 4
            )
            / many.num_chunks
            <= 0.6
        )


class TestCombinedPlans:
    """Combined fwd+bwd tick plans: the backward as first-class units,
    with the 1F1B stash bound asserted from the plan columns."""

    GRID = [
        (name, d, m, v)
        for name in ("gpipe", "one_f_one_b")
        for d in (1, 2, 4, 8)
        for m in (1, 2, 4, 5, 8, 16)
        for v in (1,)
    ] + [
        ("interleaved", d, m, v)
        for d in (2, 3, 4)
        for m in (2, 4, 5, 8)
        for v in (2, 3)
    ]

    def test_one_f_one_b_peak_stash_is_min_s_m(self):
        # THE acceptance assert: peak concurrently-stashed activations,
        # computed from the stash/release columns, is min(S, M) for the
        # 1F1B combined plan vs M for gpipe's fill-then-drain.
        for d in (2, 4, 8):
            for m in (1, 2, 4, 5, 8, 16):
                cp = build_combined_plan("one_f_one_b", d, m)
                assert cp.peak_stash_items == min(d, m), (d, m)
                cg = build_combined_plan("gpipe", d, m)
                assert cg.peak_stash_items == m, (d, m)

    def test_peak_matches_planned_closed_form(self):
        # the chunking model's backward="planned" term is exact against
        # the combined plans' own columns — measured, not assumed
        for name, d, m, v in self.GRID:
            cp = build_combined_plan(name, d, m, v)
            assert cp.peak_stash_items == schedule_peak_items(
                name, d, m, v, backward="planned"
            ), (name, d, m, v)
            assert cp.num_stash_slots == cp.peak_stash_items

    def test_autodiff_peak_is_every_unit_input(self):
        # autodiff's fwd/bwd phase boundary keeps all V*M inputs live
        # regardless of schedule name
        assert schedule_peak_items("one_f_one_b", 4, 16, backward="autodiff") == 16
        assert schedule_peak_items("gpipe", 4, 16, backward="autodiff") == 16
        assert (
            schedule_peak_items("interleaved", 4, 8, 2, backward="autodiff")
            == 16
        )
        with pytest.raises(ValueError, match="backward"):
            schedule_peak_items("gpipe", 4, 8, backward="zigzag")

    def test_every_unit_scheduled_once_and_deps_hold(self):
        for name, d, m, v, split in [
            ("gpipe", 4, 8, 1, False),
            ("one_f_one_b", 4, 8, 1, False),
            ("one_f_one_b", 4, 5, 1, True),
            ("interleaved", 2, 6, 2, False),
        ]:
            cp = build_combined_plan(name, d, m, v, split_backward=split)
            p_ = d * v
            tick_of = {}
            for t in range(cp.num_ticks):
                for dev in range(d):
                    if cp.kind[t, dev] < 0:
                        continue
                    unit = (
                        int(cp.kind[t, dev]),
                        int(cp.position[t, dev]),
                        int(cp.microbatch[t, dev]),
                    )
                    assert unit not in tick_of, unit
                    assert cp.position[t, dev] % d == dev
                    tick_of[unit] = t
            kinds = (UNIT_F, UNIT_B, UNIT_W) if split else (UNIT_F, UNIT_B)
            assert len(tick_of) == p_ * m * len(kinds)
            h = cp.handoff
            for mm in range(m):
                for p in range(p_):
                    if p > 0:
                        assert (
                            tick_of[(UNIT_F, p, mm)]
                            >= tick_of[(UNIT_F, p - 1, mm)] + h
                        )
                    if p < p_ - 1:
                        assert (
                            tick_of[(UNIT_B, p, mm)]
                            >= tick_of[(UNIT_B, p + 1, mm)] + h
                        )
                    if split:
                        assert (
                            tick_of[(UNIT_W, p, mm)] > tick_of[(UNIT_B, p, mm)]
                        )
                # loss turnaround: B at the last position strictly after F
                assert (
                    tick_of[(UNIT_B, p_ - 1, mm)] > tick_of[(UNIT_F, p_ - 1, mm)]
                )

    def test_gpipe_is_phase_gated(self):
        cp = build_combined_plan("gpipe", 4, 8)
        last_f = max(
            t
            for t in range(cp.num_ticks)
            for dev in range(4)
            if cp.kind[t, dev] == UNIT_F
        )
        first_b = min(
            t
            for t in range(cp.num_ticks)
            for dev in range(4)
            if cp.kind[t, dev] == UNIT_B
        )
        assert first_b > last_f

    def test_one_f_one_b_interleaves(self):
        # not phase-gated: some B unit runs before the last F unit
        cp = build_combined_plan("one_f_one_b", 4, 8)
        last_f = max(
            t
            for t in range(cp.num_ticks)
            for dev in range(4)
            if cp.kind[t, dev] == UNIT_F
        )
        first_b = min(
            t
            for t in range(cp.num_ticks)
            for dev in range(4)
            if cp.kind[t, dev] == UNIT_B
        )
        assert first_b < last_f

    def test_stash_release_columns_pair_up(self):
        for name in ("gpipe", "one_f_one_b"):
            cp = build_combined_plan(name, 4, 6)
            for dev in range(4):
                stashes = int((cp.stash_slot[:, dev] >= 0).sum())
                releases = int((cp.release_slot[:, dev] >= 0).sum())
                assert stashes == releases  # every stash freed exactly once
                assert (cp.stash_slot[:, dev].max() if stashes else -1) < (
                    cp.num_stash_slots
                )

    def test_split_backward_groundwork(self):
        # ZB 3-way split: W units exist, release moves to W, and the
        # stash bound is unchanged (B still consumes before W frees)
        cp = build_combined_plan("one_f_one_b", 4, 6, split_backward=True)
        assert set(np.unique(cp.kind)) >= {UNIT_F, UNIT_B, UNIT_W}
        assert cp.split_backward
        # releases happen at W ticks only
        for t in range(cp.num_ticks):
            for dev in range(4):
                if cp.release_slot[t, dev] >= 0:
                    assert cp.kind[t, dev] == UNIT_W

    def test_backward_plan_is_the_mirror(self):
        for name, d, m, v in [
            ("gpipe", 4, 8, 1),
            ("one_f_one_b", 4, 5, 1),
            ("interleaved", 2, 6, 2),
        ]:
            bp = build_backward_plan(name, d, m, v)
            fp = build_plan(name, d, m, v)
            assert bp.num_ticks == fp.num_ticks
            # cotangent seeds feed device D-1; d_items emit on device 0
            assert bp.inject_devices == (d - 1,)
            assert bp.collect[:, 0].sum() == m
            assert bp.collect[:, 1:].sum() == 0
            # every B unit once, per-position microbatch order ascending
            per_pos: dict = {}
            for t in range(bp.num_ticks):
                for dev in range(d):
                    mb = bp.microbatch[t, dev]
                    if mb >= 0:
                        pos = int(bp.group[t, dev]) * d + dev
                        per_pos.setdefault(pos, []).append(int(mb))
            assert sorted(per_pos) == list(range(d * v))
            for pos, seq in per_pos.items():
                assert seq == sorted(seq) == list(range(m)), (name, pos)

    def test_combined_plan_b_order_matches_backward_plan(self):
        # the custom-VJP bwd phase (backward plan) replays the combined
        # plan's B units: per device, identical (position, m) sequences
        for name, d, m, v in [("one_f_one_b", 4, 6, 1), ("gpipe", 4, 6, 1)]:
            cp = build_combined_plan(name, d, m, v)
            bp = build_backward_plan(name, d, m, v)
            for dev in range(d):
                comb = [
                    (int(cp.position[t, dev]), int(cp.microbatch[t, dev]))
                    for t in range(cp.num_ticks)
                    if cp.kind[t, dev] == UNIT_B
                ]
                mirror = [
                    (int(bp.group[t, dev]) * d + dev, int(bp.microbatch[t, dev]))
                    for t in range(bp.num_ticks)
                    if bp.microbatch[t, dev] >= 0
                ]
                assert comb == mirror, (name, dev)

    def test_optimal_schedule_flips_to_one_f_one_b_under_planned(self):
        # satellite: the planned backward makes 1F1B's memory advantage
        # real — a budget only its min(S, M) stash fits now selects it
        # (V=1 search: interleaving is a separate, bubble-driven win)
        kw = dict(
            max_chunks=64, memory_budget_items=0.2, interleave_options=(1,)
        )
        choice = optimal_schedule(1.0, 4, 1e-4, backward="planned", **kw)
        assert choice.schedule == "one_f_one_b"
        assert choice.peak_items / choice.num_chunks <= 0.2
        # under autodiff every schedule stashes all M: the same budget
        # is infeasible — the old model silently pretended otherwise
        with pytest.raises(ValueError, match="fits memory_budget"):
            optimal_schedule(1.0, 4, 1e-4, backward="autodiff", **kw)


class TestPlannedBackwardValidation:
    """The planned-backward executor's contract: clear errors for the
    shapes it cannot transpose (checked before any device work)."""

    def _mesh(self):
        from repro import compat

        return compat.make_mesh(
            (1,), ("pod",), devices=jax.devices()[:1]
        )

    def test_backward_mode_validated(self):
        with pytest.raises(ValueError, match="backward"):
            FutureEvaluator(self._mesh(), "pod", backward="zigzag")

    def test_mutable_state_rejected(self):
        ev = FutureEvaluator(self._mesh(), "pod", backward="planned")
        prog = StreamProgram(lambda s, x: (s + 1, x + s), jnp.zeros(2), 2)
        with pytest.raises(ValueError, match="immutable"):
            evaluate(prog, jnp.ones((2, 1)), ev)

    def test_feedback_rejected(self):
        ev = FutureEvaluator(self._mesh(), "pod", backward="planned")
        s = Stream.feedback(jnp.ones((2, 1)), 4, lambda x: x).through(
            lambda w, x: (w, x * w), jnp.ones(2), mutable_state=False
        )
        with pytest.raises(ValueError, match="feedback"):
            s.collect(ev)

    def test_multi_source_rejected(self):
        ev = FutureEvaluator(self._mesh(), "pod", backward="planned")
        s = (
            Stream.source(jnp.ones((2, 1)))
            .zip(Stream.source(jnp.ones((2, 1))), lambda a, b: a + b)
            .through(lambda w, x: (w, x * w), jnp.ones(2), mutable_state=False)
        )
        with pytest.raises(ValueError, match="single-source"):
            s.collect(ev)

    def test_integer_items_rejected(self):
        ev = FutureEvaluator(self._mesh(), "pod", backward="planned")
        prog = StreamProgram(
            lambda w, x: (w, x * 2), jnp.ones(2), 2, mutable_state=False
        )
        with pytest.raises(ValueError, match="floating-point"):
            evaluate(prog, jnp.ones((2, 1), jnp.int32), ev)

    def test_const_state_rejected(self):
        # const leaves are excluded from differentiation by construction,
        # so a planned-backward chain must refuse them loudly.
        ev = FutureEvaluator(self._mesh(), "pod", backward="planned")
        s = Stream.source(jnp.ones((2, 1))).through(
            lambda c, w, x: (w, x * w * c),
            jnp.ones(2),
            mutable_state=False,
            const_state=jnp.ones(2),
        )
        with pytest.raises(ValueError, match="const_state"):
            s.collect(ev)

    def test_pipeline_config_carries_backward(self):
        from repro.core import PipelineConfig

        cfg = PipelineConfig(
            num_stages=4, num_microbatches=8, schedule="one_f_one_b",
            backward="planned",
        )
        assert cfg.peak_stash_items == 4
        import dataclasses

        assert (
            dataclasses.replace(cfg, backward="autodiff").peak_stash_items == 8
        )
        with pytest.raises(ValueError, match="backward"):
            PipelineConfig(num_stages=4, backward="zigzag")


class TestFutureCombinators:
    def test_defer_force_identity(self):
        fut = defer(lambda: jnp.arange(3.0))
        np.testing.assert_array_equal(np.asarray(fut.force()), [0, 1, 2])

    def test_map_forwards_laziness(self):
        fut = defer(lambda: jnp.asarray(2.0)).map(lambda v: v * 3)
        assert float(fut.force()) == 6.0

    def test_force_with_anchor_inside_jit(self):
        def f(x):
            fut = defer(jnp.sin, x)
            anchor = jnp.cos(x)  # work to overlap
            return fut.force(anchor=anchor) + anchor

        x = jnp.asarray(0.7)
        assert jnp.allclose(jax.jit(f)(x), jnp.sin(x) + jnp.cos(x))

    def test_host_future(self):
        fut = HostFuture(lambda: 41).map(lambda v: v + 1)
        assert fut.force() == 42


class TestStreamProgramJit:
    def test_evaluate_inside_jit(self):
        prog = _counting_program(4)
        items = jnp.ones((3, 2))

        @jax.jit
        def run(items):
            return evaluate(prog, items)[1]

        np.testing.assert_allclose(
            np.asarray(run(items)), np.asarray(evaluate(prog, items)[1])
        )

    def test_grad_through_lazy(self):
        def cell(w, x):
            return w, jnp.tanh(x * w)

        w = jnp.full((3,), 0.5)
        prog_fn = lambda w: StreamProgram(cell, w, 3, mutable_state=False)

        def loss(w):
            _, outs = evaluate(prog_fn(w), jnp.ones((2, 1)))
            return jnp.sum(outs)

        g = jax.grad(loss)(w)
        assert g.shape == (3,)
        assert bool(jnp.all(jnp.isfinite(g)))
