"""Core Stream/Future construct: semantics, chunking math, combinators."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Future,
    LazyEvaluator,
    StreamProgram,
    bubble_fraction,
    build_plan,
    chunk_axis,
    defer,
    evaluate,
    feed_peak_items,
    optimal_num_chunks,
    optimal_schedule,
    pipeline_step_time,
    schedule_bubble_fraction,
    schedule_peak_items,
    schedule_ticks,
    unchunk_axis,
)
from repro.core.future import HostFuture


def _counting_program(num_cells):
    def cell(state, item):
        return state + 1, item * 1.5 + state.astype(jnp.float32)

    return StreamProgram(cell, jnp.arange(num_cells, dtype=jnp.int32), num_cells)


class TestLazyEvaluator:
    def test_matches_python_reference(self):
        prog = _counting_program(3)
        items = jnp.asarray([[1.0], [2.0]])
        states, outs = evaluate(prog, items, LazyEvaluator())
        # python reference with the same ordering semantics
        st_ref = np.arange(3, dtype=np.int64)
        outs_ref = []
        for it in [1.0, 2.0]:
            flow = it
            for s in range(3):
                flow = flow * 1.5 + st_ref[s]
                st_ref[s] += 1
            outs_ref.append(flow)
        np.testing.assert_array_equal(np.asarray(states), st_ref)
        np.testing.assert_allclose(np.asarray(outs)[:, 0], outs_ref, rtol=1e-6)

    def test_state_mutation_order(self):
        # each cell counts items seen: all cells see all items
        prog = _counting_program(4)
        items = jnp.ones((5, 1))
        states, _ = evaluate(prog, items)
        np.testing.assert_array_equal(
            np.asarray(states), np.arange(4) + 5
        )

    def test_immutable_state(self):
        def cell(w, x):
            return w + 1, x * w

        prog = StreamProgram(cell, jnp.ones(2), 2, mutable_state=False)
        states, outs = evaluate(prog, jnp.ones((3, 1)))
        np.testing.assert_array_equal(np.asarray(states), np.ones(2))

    def test_bad_state_shape_raises(self):
        with pytest.raises(ValueError):
            StreamProgram(lambda s, x: (s, x), jnp.zeros((3,)), 4)


class TestChunking:
    @hypothesis.given(
        st.integers(min_value=1, max_value=64),
        st.integers(min_value=1, max_value=64),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_bubble_fraction_bounds(self, s, m):
        frac = bubble_fraction(s, m)
        assert 0.0 <= frac < 1.0
        if s == 1:
            assert frac == 0.0

    @hypothesis.given(
        st.floats(min_value=1e-3, max_value=10.0),
        st.integers(min_value=2, max_value=32),
        st.floats(min_value=1e-6, max_value=1e-1),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_optimal_chunks_is_argmin(self, work, stages, overhead):
        m_star = optimal_num_chunks(work, stages, overhead)
        t_star = pipeline_step_time(work, stages, m_star, overhead)
        for m in {max(1, m_star // 2), m_star * 2, 1, 4096}:
            assert t_star <= pipeline_step_time(work, stages, m, overhead) * 1.0001

    def test_paper_primes_regime(self):
        # fine-grained cells (overhead >> work/cell): don't pipeline
        assert optimal_num_chunks(1e-4, 8, 1e-2) == 1

    def test_chunk_roundtrip(self):
        tree = {"a": jnp.arange(24).reshape(12, 2), "b": jnp.arange(12)}
        again = unchunk_axis(chunk_axis(tree, 4))
        for k in tree:
            np.testing.assert_array_equal(np.asarray(tree[k]), np.asarray(again[k]))

    def test_chunk_indivisible_raises(self):
        with pytest.raises(ValueError):
            chunk_axis(jnp.arange(10), 3)


class TestSchedulePlans:
    """The analytic chunking model must match the tick tables the
    schedules actually emit — modeled bubble == measured bubble."""

    GRID = [
        (name, d, m, v)
        for name in ("gpipe", "one_f_one_b")
        for d in (1, 2, 3, 4, 8)
        for m in (1, 2, 4, 5, 8, 16)
        for v in (1,)
    ] + [
        ("interleaved", d, m, v)
        for d in (2, 3, 4)
        for m in (1, 2, 4, 5, 8, 16)
        for v in (2, 3, 4)
    ]

    def test_model_ticks_match_plans(self):
        for name, d, m, v in self.GRID:
            plan = build_plan(name, d, m, v)
            assert plan.num_ticks == schedule_ticks(
                name, d, m, v, handoff=plan.handoff
            ), (name, d, m, v)

    def test_model_bubble_matches_plans(self):
        for name, d, m, v in self.GRID:
            plan = build_plan(name, d, m, v)
            modeled = schedule_bubble_fraction(name, d, m, v, handoff=plan.handoff)
            assert abs(plan.bubble_fraction - modeled) < 1e-9, (name, d, m, v)

    def test_interleaving_shrinks_bubble(self):
        g = build_plan("gpipe", 4, 8)
        i2 = build_plan("interleaved", 4, 8, 2)
        i4 = build_plan("interleaved", 4, 8, 4)
        assert i4.bubble_fraction < i2.bubble_fraction < g.bubble_fraction

    def test_every_unit_scheduled_once(self):
        for name, d, m, v in [("gpipe", 4, 8, 1), ("interleaved", 4, 8, 2)]:
            plan = build_plan(name, d, m, v)
            seen = set()
            for t in range(plan.num_ticks):
                for dev in range(d):
                    mb = plan.microbatch[t, dev]
                    if mb >= 0:
                        unit = (int(plan.group[t, dev]) * d + dev, int(mb))
                        assert unit not in seen
                        seen.add(unit)
            assert len(seen) == d * v * m

    def test_collection_only_on_last_stage(self):
        for name, d, m, v in [("gpipe", 4, 8, 1), ("interleaved", 4, 8, 2)]:
            plan = build_plan(name, d, m, v)
            assert plan.collect[:, : d - 1].sum() == 0
            assert plan.collect[:, d - 1].sum() == m

    def test_peak_items_ordering(self):
        # 1F1B's whole point: stash min(S, M) microbatches, not M
        assert schedule_peak_items("one_f_one_b", 4, 16) == 4
        assert schedule_peak_items("gpipe", 4, 16) == 16

    def test_optimal_schedule_joint_pick(self):
        # bubble-dominated regime: interleaving wins
        choice = optimal_schedule(1.0, 8, 1e-6, max_chunks=64)
        assert choice.schedule == "interleaved"
        # overhead-dominated: plain schedules, tiny M (paper's primes case)
        choice = optimal_schedule(1e-4, 8, 1e-2, max_chunks=64)
        assert choice.interleave == 1 and choice.num_chunks == 1
        # memory budget forces off gpipe (gpipe peak is always 1.0 items)
        choice = optimal_schedule(
            1.0, 8, 1e-4, max_chunks=256, memory_budget_items=0.5
        )
        assert choice.schedule != "gpipe"
        assert (
            schedule_peak_items(
                choice.schedule, 8, choice.num_chunks, choice.interleave
            )
            / choice.num_chunks
            <= 0.5
        )

    def test_schedule_validation(self):
        with pytest.raises(ValueError):
            build_plan("zigzag", 4, 8)
        with pytest.raises(ValueError):
            build_plan("gpipe", 4, 8, interleave=2)


class TestMultiInjectionPlans:
    """The generalized feed carousel: per-source columns for multi-source
    streams injecting at arbitrary virtual-stage boundaries."""

    GRID = [
        ("gpipe", 4, 8, 1, (0, 2)),
        ("gpipe", 4, 5, 1, (0, 0, 3)),
        ("one_f_one_b", 4, 8, 1, (0, 1)),
        ("interleaved", 4, 8, 2, (0, 5)),
        ("interleaved", 2, 6, 3, (0, 4)),
    ]

    def test_injections_never_change_the_makespan(self):
        for name, d, m, v, pos in self.GRID:
            plain = build_plan(name, d, m, v)
            multi = build_plan(name, d, m, v, inject_positions=pos)
            assert multi.num_ticks == plain.num_ticks, (name, d, m, v, pos)
            np.testing.assert_array_equal(multi.microbatch, plain.microbatch)

    def test_each_source_consumed_exactly_m_times(self):
        for name, d, m, v, pos in self.GRID:
            plan = build_plan(name, d, m, v, inject_positions=pos)
            assert plan.num_sources == len(pos)
            np.testing.assert_array_equal(
                plan.src_consume.sum(axis=1), [m] * len(pos)
            )

    def test_reload_every_dth_consumption(self):
        for name, d, m, v, pos in self.GRID:
            plan = build_plan(name, d, m, v, inject_positions=pos)
            for s in range(len(pos)):
                # reloads happen on consumptions 0, D, 2D, ...
                assert plan.src_feed_reload[s].sum() == -(-m // d)
                ticks = np.nonzero(plan.src_feed_reload[s])[0]
                np.testing.assert_array_equal(
                    plan.src_feed_idx[s][ticks], np.arange(len(ticks))
                )

    def test_inject_devices_follow_positions(self):
        plan = build_plan("interleaved", 4, 8, 2, inject_positions=(0, 5))
        assert plan.inject_devices == (0, 1)  # virtual stage 5 on device 1

    def test_legacy_columns_alias_source_zero(self):
        plan = build_plan("gpipe", 4, 8, inject_positions=(0, 2))
        np.testing.assert_array_equal(plan.feed_reload, plan.src_feed_reload[0])
        np.testing.assert_array_equal(plan.feed_idx, plan.src_feed_idx[0])
        np.testing.assert_array_equal(plan.feed_advance, plan.src_feed_advance[0])
        np.testing.assert_array_equal(plan.inject, plan.src_consume[0])

    def test_position_validation(self):
        with pytest.raises(ValueError, match="chain entry"):
            build_plan("gpipe", 4, 8, inject_positions=(1,))
        with pytest.raises(ValueError, match="outside"):
            build_plan("gpipe", 4, 8, inject_positions=(0, 4))
        with pytest.raises(ValueError, match="outside"):
            build_plan("interleaved", 4, 8, 2, inject_positions=(0, 8))

    def test_plan_peak_charges_its_own_sources(self):
        # the plan's self-reported peak must use the same multi-source
        # model optimal_schedule budgets against
        single = build_plan("gpipe", 4, 8)
        multi = build_plan("gpipe", 4, 8, inject_positions=(0, 2))
        assert single.peak_inflight_items == 8
        assert multi.peak_inflight_items == schedule_peak_items(
            "gpipe", 4, 8, num_sources=2
        )
        assert multi.peak_inflight_items > single.peak_inflight_items

    def test_feed_memory_terms(self):
        # one source: shard + register; each extra source adds the same
        assert feed_peak_items(4, 8, 1) == 3
        assert feed_peak_items(4, 8, 2) == 6
        assert feed_peak_items(4, 5, 2) == 2 * (2 + 1)
        with pytest.raises(ValueError):
            feed_peak_items(4, 8, 0)
        # schedule peak charges extra sources' feeds, primary grandfathered
        base = schedule_peak_items("one_f_one_b", 4, 16)
        multi = schedule_peak_items("one_f_one_b", 4, 16, num_sources=3)
        assert multi == base + 2 * (4 + 1)

    def test_multi_source_budget_shifts_choice(self):
        # same regime, but feed storage charged against the budget: more
        # sources must never *relax* the constraint
        one = optimal_schedule(
            1.0, 8, 1e-4, max_chunks=256, memory_budget_items=0.6
        )
        many = optimal_schedule(
            1.0, 8, 1e-4, max_chunks=256, memory_budget_items=0.6, num_sources=4
        )
        assert many.peak_items >= one.peak_items
        assert (
            schedule_peak_items(
                many.schedule, 8, many.num_chunks, many.interleave, 4
            )
            / many.num_chunks
            <= 0.6
        )


class TestFutureCombinators:
    def test_defer_force_identity(self):
        fut = defer(lambda: jnp.arange(3.0))
        np.testing.assert_array_equal(np.asarray(fut.force()), [0, 1, 2])

    def test_map_forwards_laziness(self):
        fut = defer(lambda: jnp.asarray(2.0)).map(lambda v: v * 3)
        assert float(fut.force()) == 6.0

    def test_force_with_anchor_inside_jit(self):
        def f(x):
            fut = defer(jnp.sin, x)
            anchor = jnp.cos(x)  # work to overlap
            return fut.force(anchor=anchor) + anchor

        x = jnp.asarray(0.7)
        assert jnp.allclose(jax.jit(f)(x), jnp.sin(x) + jnp.cos(x))

    def test_host_future(self):
        fut = HostFuture(lambda: 41).map(lambda v: v + 1)
        assert fut.force() == 42


class TestStreamProgramJit:
    def test_evaluate_inside_jit(self):
        prog = _counting_program(4)
        items = jnp.ones((3, 2))

        @jax.jit
        def run(items):
            return evaluate(prog, items)[1]

        np.testing.assert_allclose(
            np.asarray(run(items)), np.asarray(evaluate(prog, items)[1])
        )

    def test_grad_through_lazy(self):
        def cell(w, x):
            return w, jnp.tanh(x * w)

        w = jnp.full((3,), 0.5)
        prog_fn = lambda w: StreamProgram(cell, w, 3, mutable_state=False)

        def loss(w):
            _, outs = evaluate(prog_fn(w), jnp.ones((2, 1)))
            return jnp.sum(outs)

        g = jax.grad(loss)(w)
        assert g.shape == (3,)
        assert bool(jnp.all(jnp.isfinite(g)))
