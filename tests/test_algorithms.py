"""Paper algorithms: limb arithmetic, sparse polynomials, prime sieve."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import limb
from repro.algorithms import polynomial as poly
from repro.algorithms import sieve


class TestLimb:
    @hypothesis.given(st.integers(0, 2**90 - 1), st.integers(0, 2**90 - 1))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_add_matches_bigint(self, a, b):
        la, lb = limb.from_int(a, 8), limb.from_int(b, 8)
        assert limb.to_int(limb.add(la, lb)) == (a + b) % (1 << (13 * 8))

    @hypothesis.given(st.integers(0, 2**50 - 1), st.integers(0, 2**50 - 1))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_mul_matches_bigint(self, a, b):
        la, lb = limb.from_int(a, 8), limb.from_int(b, 8)
        assert limb.to_int(limb.mul(la, lb)) == (a * b) % (1 << (13 * 8))

    def test_overflow_raises(self):
        with pytest.raises(OverflowError):
            limb.from_int(1 << 26, 2)

    def test_is_zero(self):
        assert bool(limb.is_zero(limb.from_int(0, 4)))
        assert not bool(limb.is_zero(limb.from_int(7, 4)))

    def test_batched_mul(self):
        a = jnp.stack([limb.from_int(v, 6) for v in (3, 1 << 30, 12345)])
        b = limb.from_int(99991, 6)
        out = limb.mul(a, b[None, :])
        for i, v in enumerate((3, 1 << 30, 12345)):
            assert limb.to_int(out[i]) == v * 99991


@st.composite
def small_poly(draw, max_terms=6, max_exp=5, max_coef=1 << 20):
    n = draw(st.integers(1, max_terms))
    terms = {}
    for _ in range(n):
        e = tuple(draw(st.integers(0, max_exp)) for _ in range(3))
        terms[e] = draw(st.integers(1, max_coef))
    return terms


class TestPolynomial:
    @hypothesis.given(small_poly(), small_poly())
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_times_matches_bigint_oracle(self, tx, ty):
        x = poly.from_dict(tx, 8, 8)
        y = poly.from_dict(ty, 8, 8)
        ref = poly.reference_product(tx, ty)
        got = poly.to_dict(
            poly.times(x, y, num_x_chunks=2, terms_per_cell=2, acc_capacity=128)
        )
        assert got == ref

    @hypothesis.given(small_poly(), small_poly())
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_dense_matches_stream(self, tx, ty):
        x = poly.from_dict(tx, 8, 8)
        y = poly.from_dict(ty, 8, 8)
        assert poly.to_dict(poly.times_dense(x, y, capacity=128)) == (
            poly.reference_product(tx, ty)
        )

    def test_plus_cancellation_clears_lane(self):
        # modular wraparound makes a + b ≡ 0: the lane must clear
        mod = 1 << (13 * 4)
        a = poly.from_dict({(1, 0, 0): 5}, 4, 4)
        b = poly.from_dict({(1, 0, 0): mod - 5}, 4, 4)
        out = poly.plus(a, b, capacity=8)
        assert poly.to_dict(out) == {}
        assert int(poly.num_terms(out)) == 0

    def test_fateman_big_factor(self):
        x = poly.fateman_poly(3, 32, 12, big_factor=100000000001)
        ref = poly.reference_product(poly.to_dict(x), poly.to_dict(x))
        got = poly.to_dict(
            poly.times(x, x, num_x_chunks=2, terms_per_cell=4, acc_capacity=512)
        )
        assert got == ref

    def test_key_packing_roundtrip(self):
        for e in [(0, 0, 0), (5, 3, 1), (40, 40, 40)]:
            assert poly.unpack_key(poly.pack_key(e)) == e

    def test_times_into_under_jit(self):
        # the accumulator seeding must stay traceable: z arrives as a
        # tracer when the fused multiply-add is jitted like times is
        tx, tz = {(1, 0, 0): 3, (0, 2, 0): 5}, {(2, 2, 0): 7}
        x = poly.from_dict(tx, 8, 8)
        z = poly.from_dict(tz, 8, 8)
        fma = jax.jit(
            lambda z_: poly.times_into(
                x, x, z_, num_x_chunks=4, terms_per_cell=2, acc_capacity=256
            )
        )
        ref = poly.reference_product(tx, tx)
        for k, v in tz.items():
            ref[k] = ref.get(k, 0) + v
        assert poly.to_dict(fma(z)) == ref


class TestSieve:
    @hypothesis.given(st.integers(10, 1200))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_matches_eratosthenes(self, limit):
        ref = sieve.reference_primes(limit)
        p, count = sieve.run_sieve(limit, block_size=64, primes_per_cell=4)
        p = np.asarray(p)
        assert int(count) == len(ref)
        np.testing.assert_array_equal(p[p > 0], ref)

    def test_chunking_invariance(self):
        # paper §7: grouping cells must not change the result
        ref = sieve.reference_primes(500)
        for k in (1, 2, 8):
            p, _ = sieve.run_sieve(500, block_size=32, primes_per_cell=k)
            p = np.asarray(p)
            np.testing.assert_array_equal(p[p > 0], ref)
