"""Sharding rule resolution, fit_spec properties, HLO parsing, analytic flops."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import numpy as np
from jax.sharding import PartitionSpec as P

import jax

from repro.parallel import sharding as SH
from repro.roofline import analytic as AN
from repro.roofline.hlo_parse import analyze_hlo, loop_multipliers, parse_module, shape_bytes


class FakeMesh:
    """Duck-typed mesh: only .axis_names and .shape are consulted."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestFitSpec:
    @hypothesis.given(
        st.lists(st.sampled_from([None, "data", "model", ("data", "model")]),
                 min_size=1, max_size=4),
        st.lists(st.sampled_from([1, 8, 16, 20, 24, 64, 256, 50280]),
                 min_size=1, max_size=4),
    )
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_always_legal(self, parts, dims):
        n = min(len(parts), len(dims))
        spec, shape = P(*parts[:n]), tuple(dims[:n])
        out = SH.fit_spec(spec, shape, MESH)
        used = []
        for d, part in enumerate(out):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            prod = int(np.prod([MESH.shape[a] for a in axes]))
            assert shape[d] % prod == 0  # divisibility
            used.extend(axes)
        assert len(used) == len(set(used))  # no duplicate mesh axes

    def test_dedup_keeps_first(self):
        out = SH.fit_spec(P("model", "model"), (32, 32), MESH)
        assert out == P("model")

    def test_indivisible_heads_replicated(self):
        out = SH.fit_spec(P(None, "data", "model"), (48, 1536, 24), MESH)
        assert out == P(None, "data")

    def test_tuple_axis_partial_drop(self):
        # 32 % (2*16) == 0 keeps both; 16 % 32 != 0 drops from the right
        assert SH.fit_spec(P(("pod", "data")), (32,), MESH3) == P(("pod", "data"))
        # normalized singleton: P("pod"), not P(("pod",)) (equal on modern
        # JAX, distinct objects on 0.4.x)
        assert SH.fit_spec(P(("pod", "data")), (2,), MESH3) == P("pod")

    def test_prune_removes_missing_axes(self):
        assert SH.prune_spec(P(("pod", "data"), "model"), MESH) == P("data", "model")

    def test_rules_have_no_conflicts_per_ruleset(self):
        from repro.models.transformer import cache_logical_axes
        from repro.configs.registry import ARCH_IDS, get_config

        for rules in (SH.DECODE_RULES, SH.PREFILL_RULES, SH.LONG_DECODE_RULES):
            for arch in ARCH_IDS:
                axes = cache_logical_axes(get_config(arch))
                for leaf_axes in jax.tree.leaves(
                    axes, is_leaf=lambda x: isinstance(x, tuple)
                ):
                    spec = SH.spec_for(leaf_axes, rules)
                    SH.fit_spec(spec, (48, 256, 512, 16, 128)[: len(leaf_axes)], MESH3)


SAMPLE_HLO = """
HloModule test

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128] get-tuple-element(%p), index=1
  %ar = f32[8,128] all-reduce(%x), to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128] parameter(0)
  %ag = f32[16,128] all-gather(%a), dimensions={0}
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,128]) tuple(%z, %a)
  %w = (s32[], f32[8,128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,128] get-tuple-element(%w), index=1
}
"""


class TestHloParse:
    def test_shape_bytes(self):
        assert shape_bytes("f32[8,128]") == 8 * 128 * 4
        assert shape_bytes("bf16[2,3]{1,0}") == 12
        assert shape_bytes("(s32[], f32[4,4])") == 4 + 64

    def test_loop_multiplier_applied(self):
        out = analyze_hlo(SAMPLE_HLO)
        # all-reduce inside 10-trip loop: 10 × 4096B × 2 (ring factor)
        assert out["collective_bytes_by_kind"]["all-reduce"] == 10 * 8 * 128 * 4
        assert out["collective_bytes_by_kind"]["all-gather"] == 16 * 128 * 4
        assert out["collective_counts_dynamic"]["all-reduce"] == 10
        assert out["collective_counts_static"]["all-reduce"] == 1

    def test_multipliers(self):
        comps, entry = parse_module(SAMPLE_HLO)
        mult = loop_multipliers(comps, entry)
        assert mult[entry] == 1.0
        assert mult["body"] == 10.0

    def test_real_compiled_module_parses(self):
        import jax.numpy as jnp

        def f(x):
            def step(c, _):
                return c * 2.0, None
            out, _ = jax.lax.scan(step, x, None, length=7)
            return out

        hlo = jax.jit(f).lower(jnp.ones((4, 4))).compile().as_text()
        out = analyze_hlo(hlo)
        assert out["num_loops"] >= 0  # parses without error


class TestConditionalGuard:
    """The emit-split checker must be *sound*: an unconditional head
    matmul may never count as guarded — including when XLA fuses it
    (fusion bodies are referenced via ``calls=``, which the unguarded
    BFS must traverse)."""

    V = 2048

    def _w(self):
        import jax.numpy as jnp

        return jnp.zeros((64, self.V), jnp.float32)

    def test_unconditional_fused_head_is_flagged(self):
        import jax.numpy as jnp

        from repro.roofline.hlo_parse import head_matmul_conditional_only

        w = self._w()
        # + bias so the dot fuses on CPU: the checker must still see it
        f = jax.jit(lambda x: jnp.tanh(x @ w + 1.0))
        hlo = f.lower(jnp.zeros((4, 64), jnp.float32)).compile().as_text()
        assert "calls=" in hlo  # the fusion edge this test pins
        assert head_matmul_conditional_only(hlo, self.V) is False

    def test_cond_guarded_head_passes(self):
        import jax.numpy as jnp
        from jax import lax

        from repro.roofline.hlo_parse import head_matmul_conditional_only

        w = self._w()
        g = jax.jit(
            lambda p, x: lax.cond(
                p > 0,
                lambda y: jnp.tanh(y @ w + 1.0),
                lambda y: jnp.zeros((4, self.V)),
                x,
            )
        )
        hlo = g.lower(
            jnp.int32(0), jnp.zeros((4, 64), jnp.float32)
        ).compile().as_text()
        assert head_matmul_conditional_only(hlo, self.V) is True

    def test_no_head_at_all_is_not_a_pass(self):
        import jax.numpy as jnp

        from repro.roofline.hlo_parse import head_matmul_conditional_only

        f = jax.jit(lambda x: x * 2.0)
        hlo = f.lower(jnp.zeros((4, 64), jnp.float32)).compile().as_text()
        # total == 0 must fail: "no matmul found" is a broken probe,
        # not a guarded one
        assert head_matmul_conditional_only(hlo, self.V) is False


class TestAnalyticFlops:
    def test_dense_matches_hand_count(self):
        from repro.configs.base import ArchConfig, ShapeCell

        cfg = ArchConfig(
            name="tiny", family="dense", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        )
        shape = ShapeCell("t", seq_len=32, global_batch=2, kind="prefill")
        f = AN.forward_flops(cfg, shape.tokens, 2, 32)
        t = shape.tokens
        # qkv+o proj: 2*t*d*(h+2kv)*dh + 2*t*h*dh*d
        proj = 2 * t * 64 * (4 + 8) * 16 + 2 * t * 4 * 16 * 64
        attn = 2 * 2 * t * 32 * 4 * 16
        ffn = 2 * 3 * t * 64 * 128
        head = 2 * t * 64 * 256
        assert f["proj"] == proj * 2
        assert f["attn"] == attn * 2
        assert f["ffn"] == ffn * 2
        assert f["head"] == head

    def test_train_multiplier(self):
        from repro.configs.base import SHAPES
        from repro.configs.registry import get_config

        cfg = get_config("olmo-1b")
        tr = AN.step_flops(cfg, SHAPES["train_4k"], remat=True)["total"]
        no_remat = AN.step_flops(cfg, SHAPES["train_4k"], remat=False)["total"]
        assert tr > no_remat

    def test_moe_counts_active_only(self):
        from repro.configs.base import SHAPES
        from repro.configs.registry import get_config

        cfg = get_config("llama4-maverick-400b-a17b")
        f = AN.step_flops(cfg, SHAPES["prefill_32k"])["total"]
        # active ~17B params at 1M tokens: 2ND = 3.5e16; full 400B would be 8e17.
        assert f < 3e17

    def test_decode_flops_scale_with_batch_not_seq(self):
        from repro.configs.base import SHAPES
        from repro.configs.registry import get_config

        cfg = get_config("qwen3-32b")
        dec = AN.step_flops(cfg, SHAPES["decode_32k"])["total"]
        pre = AN.step_flops(cfg, SHAPES["prefill_32k"])["total"]
        assert dec < pre / 100
