"""Stream combinator algebra: laws, IR shape, lowering, validation.

Single-device tests — Lazy ≡ Future bit-equality for every combinator on
every schedule runs in the multidevice battery (test_multidevice.py).
"""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LazyEvaluator, Stream, StreamProgram, evaluate
from repro.core import graph as G


def _items(m=6, w=3, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(m, w)).astype(np.float32)
    )


def _count_cell(state, item):
    return state + 1, item * 1.5 + state.astype(jnp.float32)


class TestMapFusion:
    def test_map_map_builds_one_node(self):
        f = lambda x: x * 2.0
        g = lambda x: x + 1.0
        items = _items()
        fused = Stream.source(items).map(f).map(g)
        direct = Stream.source(items).map(lambda x: g(f(x)))
        assert len(fused.nodes()) == len(direct.nodes()) == 2
        assert sum(isinstance(n, G.MapNode) for n in fused.nodes()) == 1

    def test_map_map_values_equal(self):
        f = lambda x: x * 2.0
        g = lambda x: jnp.tanh(x)
        items = _items()
        a = Stream.source(items).map(f).map(g).collect().items
        b = Stream.source(items).map(lambda x: g(f(x))).collect().items
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @hypothesis.given(st.integers(1, 5))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_map_chain_always_one_node(self, n):
        s = Stream.source(_items())
        for i in range(n):
            s = s.map(lambda x, _i=i: x + float(_i))
        assert sum(isinstance(nd, G.MapNode) for nd in s.nodes()) == 1

    def test_map_fuses_into_segment_lowering(self):
        """A spine map leaves no standalone stage: one fused segment."""
        s = (
            Stream.source(_items())
            .map(lambda x: x * 2.0)
            .through(_count_cell, jnp.arange(4, dtype=jnp.int32))
            .map(lambda x: x + 1.0)
        )
        chain = s.lower()
        assert len(chain.segments) == 1
        assert chain.num_cells == 4
        assert chain.finalize is not None  # the tail map


class TestConcatAssociativity:
    def test_ir_shape_identical(self):
        a, b, c = (Stream.source(_items(seed=i)) for i in range(3))
        left = a.concat(b).concat(c)
        a2, b2, c2 = (Stream.source(_items(seed=i)) for i in range(3))
        right = a2.concat(b2.concat(c2))
        count = lambda s: sum(isinstance(n, G.ConcatNode) for n in s.nodes())
        assert count(left) == count(right) == 2

    def test_values_bit_equal(self):
        xs = [_items(seed=i) for i in range(3)]
        left = (
            Stream.source(xs[0]).concat(Stream.source(xs[1])).concat(Stream.source(xs[2]))
        )
        right = Stream.source(xs[0]).concat(
            Stream.source(xs[1]).concat(Stream.source(xs[2]))
        )
        np.testing.assert_array_equal(
            np.asarray(left.collect().items), np.asarray(right.collect().items)
        )

    def test_concat_lengths_add(self):
        s = Stream.source(_items(4)).concat(Stream.source(_items(3)))
        assert s.num_items == 7

    def test_concat_structure_mismatch_raises_at_construction(self):
        a = Stream.source({"x": _items()})
        b = Stream.source({"y": _items()})
        with pytest.raises(ValueError, match="structure"):
            a.concat(b)
        # masked sources also have statically known structure
        with pytest.raises(ValueError, match="structure"):
            a.mask(lambda i: i["x"] > 0).concat(b)

    def test_concat_structure_mismatch_raises_after_map_at_eval(self):
        # a map's output structure is unknowable at construction; the
        # check falls back to eval time with the same error either path
        a = Stream.source(_items()).map(lambda i: {"x": i})
        b = Stream.source({"y": _items()})
        s = a.concat(b)
        with pytest.raises(ValueError, match="structure"):
            s.collect()


class TestZipDeterminism:
    def test_source_order_not_arrival_order(self):
        """Item b of x.zip(y, f) is f(x[b], y[b]) — a pure function of the
        sources, so swapping the zip's sides with a flipped combine is
        the identical program."""
        x, y = _items(seed=1), _items(seed=2)
        ab = Stream.source(x).zip(Stream.source(y), lambda a, b: (a, b))
        ba = Stream.source(y).zip(Stream.source(x), lambda b, a: (a, b))
        ra, rb = ab.collect().items, ba.collect().items
        for u, v in zip(jax.tree.leaves(ra), jax.tree.leaves(rb)):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))

    def test_repeated_runs_identical(self):
        x, y = _items(seed=1), _items(seed=2)
        s = Stream.source(x).zip(Stream.source(y), lambda a, b: a * b + a)
        r1 = s.collect().items
        r2 = s.collect().items
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    def test_zip_length_mismatch_raises(self):
        with pytest.raises(ValueError, match="equal stream lengths"):
            Stream.source(_items(4)).zip(
                Stream.source(_items(5)), lambda a, b: a
            )

    def test_structure_changing_mid_spine_mask_raises_clearly(self):
        """A mask between two segments changes the flowing structure; the
        pipelined executor cannot run it (ring buffers are shape-static)
        and must say so, not die in a lax.cond type mismatch."""
        w = jnp.arange(2, dtype=jnp.int32)
        masked_cell = lambda s, i: (
            s + 1,
            {"value": i["value"] * 1.5, "valid": i["valid"]},
        )
        s = (
            Stream.source(_items())
            .through(_count_cell, w)
            .mask(lambda i: i > 0.0)
            .through(masked_cell, w)
        )
        out = s.collect(LazyEvaluator()).items  # general DAG: fine
        assert out["value"].shape == (6, 3)
        chain = s.lower()
        uni = G.unify_segments(chain.segments)
        row0 = jax.tree.map(lambda l: l[0], uni.init_state)
        with pytest.raises(ValueError, match="LazyEvaluator"):
            # canonical 3-arg cell: const row (None here), state row, item
            uni.cell_fn(None, row0, _items()[0])

    def test_zip_of_stateful_pipelines_runs_lazy_but_not_chain(self):
        w = jnp.arange(2, dtype=jnp.int32)
        left = Stream.source(_items()).through(_count_cell, w)
        right = Stream.source(_items(seed=5)).through(_count_cell, w)
        z = left.zip(right, lambda a, b: a + b)
        out = z.collect(LazyEvaluator()).items  # general DAG: fine
        assert out.shape == (6, 3)
        with pytest.raises(ValueError, match="LazyEvaluator"):
            z.lower()


class TestMask:
    def test_mask_tags_validity(self):
        vals = jnp.arange(6.0)
        out = Stream.source(vals).mask(lambda v: v > 2.5).collect().items
        np.testing.assert_array_equal(
            np.asarray(out["valid"]), np.arange(6) > 2.5
        )
        np.testing.assert_array_equal(np.asarray(out["value"]), np.arange(6.0))


class TestThroughComposition:
    def test_two_segments_match_one(self):
        """Chained .through segments ≡ one longer chain (same cells)."""
        w = jnp.arange(6, dtype=jnp.int32)
        items = _items()
        one = Stream.source(items).through(_count_cell, w)
        two = (
            Stream.source(items)
            .through(_count_cell, w[:3])
            .through(_count_cell, w[3:])
        )
        r1, r2 = one.collect(), two.collect()
        np.testing.assert_array_equal(np.asarray(r1.items), np.asarray(r2.items))
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate([r2.states[0], r2.states[1]])),
            np.asarray(r1.states[0]),
        )

    def test_num_cells_inferred(self):
        s = Stream.source(_items()).through(_count_cell, jnp.zeros(5, jnp.int32))
        assert s.num_cells == 5

    def test_state_shape_mismatch_raises(self):
        with pytest.raises(ValueError, match="num_cells"):
            Stream.source(_items()).through(
                _count_cell, jnp.zeros(5, jnp.int32), num_cells=4
            )


class TestInputValidation:
    """Satellite: evaluators must reject malformed item pytrees loudly."""

    def test_empty_pytree_raises(self):
        prog = StreamProgram(_count_cell, jnp.zeros(2, jnp.int32), 2)
        with pytest.raises(ValueError, match="empty pytree"):
            evaluate(prog, {}, LazyEvaluator())

    def test_mismatched_leading_axes_raise(self):
        prog = StreamProgram(_count_cell, jnp.zeros(2, jnp.int32), 2)
        bad = {"a": jnp.zeros((4, 2)), "b": jnp.zeros((5, 2))}
        with pytest.raises(ValueError, match="leading"):
            evaluate(prog, bad, LazyEvaluator())

    def test_source_validates_too(self):
        with pytest.raises(ValueError, match="leading"):
            Stream.source({"a": jnp.zeros((4, 2)), "b": jnp.zeros((5, 2))})
        with pytest.raises(ValueError, match="empty pytree"):
            Stream.source({})

    def test_scalar_leaf_raises(self):
        with pytest.raises(ValueError, match="leading stream axis"):
            Stream.source(jnp.float32(1.0))

    def test_stream_with_items_arg_raises(self):
        s = Stream.source(_items())
        with pytest.raises(ValueError, match="its own sources"):
            evaluate(s, _items(), LazyEvaluator())


class TestFromProgram:
    def test_adapter_equivalence_and_deprecation(self):
        prog = StreamProgram(_count_cell, jnp.arange(4, dtype=jnp.int32), 4)
        items = _items()
        st_legacy, out_legacy = evaluate(prog, items, LazyEvaluator())
        with pytest.warns(DeprecationWarning, match="from_program"):
            res = Stream.from_program(prog, items).collect()
        np.testing.assert_array_equal(np.asarray(out_legacy), np.asarray(res.items))
        np.testing.assert_array_equal(
            np.asarray(st_legacy), np.asarray(res.states[0])
        )

    def test_legacy_evaluate_path_does_not_warn(self):
        """The StreamProgram adapter inside evaluate() builds the graph
        directly — deprecation fires only on explicit from_program use."""
        import warnings

        prog = StreamProgram(_count_cell, jnp.arange(4, dtype=jnp.int32), 4)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            evaluate(prog, _items(), LazyEvaluator())

    def test_adapter_forwards_program_options(self):
        """mutable_state/remat/num_cells survive the adapter — the
        lowered segment must be indistinguishable from a direct
        .through() build."""
        prog = StreamProgram(
            lambda w, x: (w, x * w[0]), jnp.arange(1.0, 4.0).reshape(3, 1), 3,
            mutable_state=False, remat=True,
        )
        with pytest.warns(DeprecationWarning):
            stream = Stream.from_program(prog, _items())
        seg = stream.lower().segments[0]
        assert seg.num_cells == 3
        assert seg.mutable_state is False
        assert seg.remat is True

    def test_adapter_grad_matches_direct_build(self):
        """jax.grad through the adapter equals the direct algebra build
        bitwise (the adapter adds no ops)."""
        w0 = jnp.linspace(0.2, 0.8, 3)
        items = _items()

        def cell(w, x):
            return w, jnp.tanh(x * w)

        def loss_adapter(w):
            import warnings

            prog = StreamProgram(cell, w, 3, mutable_state=False)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                res = Stream.from_program(prog, items).collect()
            return jnp.sum(res.items ** 2)

        def loss_direct(w):
            res = (
                Stream.source(items)
                .through(cell, w, mutable_state=False)
                .collect()
            )
            return jnp.sum(res.items ** 2)

        ga = jax.grad(loss_adapter)(w0)
        gd = jax.grad(loss_direct)(w0)
        np.testing.assert_array_equal(np.asarray(ga), np.asarray(gd))


class TestFeedback:
    """The unfold combinator: item b >= lag is emit(out[b - lag])."""

    def _emit(self, item):
        return item * 0.5 + 1.0

    def _reference(self, init, n, states0, emit):
        from jax import lax

        lag = init.shape[0]

        def run_item(states, flow):
            def c(fl, s):
                ns, out = _count_cell(s, fl)
                return out, ns

            out, ns = lax.scan(c, flow, states)
            return ns, out

        ring = [init[i] for i in range(lag)]
        states, outs = states0, []
        for b in range(n):
            inp = ring.pop(0) if b < lag else outs[b - lag]
            states, raw = run_item(states, inp)
            outs.append(emit(raw))
        return jnp.stack(outs), states

    @pytest.mark.parametrize("lag,n", [(1, 5), (3, 14), (4, 4)])
    def test_lazy_matches_unrolled_reference(self, lag, n):
        init = jnp.asarray(
            np.random.default_rng(1).normal(size=(lag, 3)).astype(np.float32)
        )
        states0 = jnp.arange(4, dtype=jnp.int32)
        res = (
            Stream.feedback(init, n, self._emit)
            .through(_count_cell, states0)
            .collect(LazyEvaluator())
        )
        ref_items, ref_states = self._reference(init, n, states0, self._emit)
        np.testing.assert_allclose(
            np.asarray(res.items), np.asarray(ref_items), rtol=1e-6
        )
        np.testing.assert_array_equal(
            np.asarray(res.states[0]), np.asarray(ref_states)
        )

    def test_entry_zip_overlay(self):
        """An entry zip merges into fed-back items too (the admission
        overlay): items where the source gates are replaced wholesale,
        so their outputs depend only on the overlay value."""
        from jax import lax

        lag, n = 2, 8
        init = jnp.ones((lag, 3))
        overlay = jnp.where(
            (jnp.arange(n) % 3 == 0)[:, None], jnp.full((n, 3), 5.0), 0.0
        )
        combine = lambda flow, src: jnp.where(src > 0, src, flow)
        cell = lambda w, x: (w, jnp.tanh(x * w))  # stateless: directly checkable
        weights = jnp.linspace(0.5, 1.5, 4)
        res = (
            Stream.feedback(init, n, self._emit)
            .zip(Stream.source(overlay), combine)
            .through(cell, weights, mutable_state=False)
            .collect(LazyEvaluator())
        )

        def chain_one(x):
            out, _ = lax.scan(lambda fl, w: (jnp.tanh(fl * w), w), x, weights)
            return self._emit(out)

        # gated items (0, 3, 6) — including the *fed-back* items 3 and 6
        # — must equal running the chain on the overlay value alone.
        expect = chain_one(jnp.full((3,), 5.0))
        for b in (0, 3, 6):
            np.testing.assert_allclose(
                np.asarray(res.items[b]), np.asarray(expect), rtol=1e-6
            )
        # a non-gated fed-back item really is emit(chain(prev emitted))
        np.testing.assert_allclose(
            np.asarray(res.items[4]),
            np.asarray(chain_one(res.items[2])),
            rtol=1e-6,
        )

    def test_num_items_and_lag_validation(self):
        with pytest.raises(ValueError, match="num_items"):
            Stream.feedback(jnp.zeros((4, 2)), 3, self._emit)

    def test_lazy_eval_graph_rejects_feedback(self):
        s = Stream.feedback(jnp.zeros((2, 3)), 6, self._emit).through(
            _count_cell, jnp.zeros(2, jnp.int32)
        )
        with pytest.raises(TypeError, match="node-local"):
            G.lazy_eval_graph(s.node)

    def test_emit_must_preserve_structure(self):
        s = Stream.feedback(
            jnp.zeros((2, 3)), 6, lambda item: {"changed": item}
        ).through(_count_cell, jnp.zeros(2, jnp.int32))
        with pytest.raises(ValueError, match="preserve the flowing item"):
            s.collect(LazyEvaluator())

    def test_tail_zip_rejected(self):
        src = Stream.source(jnp.zeros((6, 3)))
        s = (
            Stream.feedback(jnp.zeros((2, 3)), 6, self._emit)
            .through(_count_cell, jnp.zeros(2, jnp.int32))
            .zip(src, lambda a, b: a + b)
        )
        with pytest.raises(ValueError, match="after the last cell"):
            s.lower()

    def test_tail_map_folds_into_emit(self):
        """Maps after the last segment run before the emit — the
        collected items are the emitted (post-tail-map) values."""
        init = jnp.ones((2, 3))
        base = Stream.feedback(init, 6, self._emit).through(
            _count_cell, jnp.zeros(2, jnp.int32)
        )
        mapped = (
            Stream.feedback(init, 6, lambda it: self._emit(it * 2.0))
            .through(_count_cell, jnp.zeros(2, jnp.int32))
        )
        with_tail = (
            Stream.feedback(init, 6, self._emit)
            .through(_count_cell, jnp.zeros(2, jnp.int32))
            .map(lambda x: x * 2.0)
        )
        a = with_tail.collect(LazyEvaluator()).items
        b = mapped.collect(LazyEvaluator()).items
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert with_tail.lower().finalize is None

    def test_plan_has_feedback_lag(self):
        from repro.core.schedules import build_plan

        p = build_plan("gpipe", 4, 16, feedback_lag=8)
        assert p.feedback_lag == 8
        # every (position, item) unit scheduled exactly once
        assert int((p.microbatch >= 0).sum()) == 4 * 16


class TestLowering:
    def test_entry_zip_two_injections(self):
        x, y = _items(seed=1), _items(seed=2)
        s = (
            Stream.source(x)
            .zip(Stream.source(y), lambda a, b: a + b)
            .through(_count_cell, jnp.arange(4, dtype=jnp.int32))
        )
        chain = s.lower()
        assert len(chain.injections) == 2
        assert [i.cell_index for i in chain.injections] == [0, 0]
        assert chain.injections[0].combine is None
        assert chain.injections[1].combine is not None

    def test_interior_zip_cell_index(self):
        x, y = _items(seed=1), _items(seed=2)
        s = (
            Stream.source(x)
            .through(_count_cell, jnp.arange(4, dtype=jnp.int32))
            .zip(Stream.source(y), lambda a, b: a + b)
            .through(_count_cell, jnp.arange(2, dtype=jnp.int32))
        )
        chain = s.lower()
        assert chain.num_cells == 6
        assert [i.cell_index for i in chain.injections] == [0, 4]

    def test_pure_program_zero_cells(self):
        s = Stream.source(_items()).map(lambda x: x * 3.0)
        chain = s.lower()
        assert chain.num_cells == 0 and len(chain.segments) == 0

    def test_lazy_future_zero_cell_paths_agree(self):
        from repro.core.stream import FutureEvaluator  # noqa: F401
        s = Stream.source(_items()).map(lambda x: x * 3.0)
        # Zero-cell chains never enter the pipeline region, so the Future
        # evaluator's chain path is pure data plumbing — exercised here
        # without a mesh via the lowered chain itself.
        chain = s.lower()
        outs = chain.injections[0].materialize()
        np.testing.assert_array_equal(
            np.asarray(outs), np.asarray(s.collect().items)
        )


class TestConstState:
    """The read-only/mutable state split: ``through(..., const_state=...)``.

    Const leaves ride scan xs only — same values as folding them into
    the mutable state, minus the per-tick write-back (and minus an entry
    in the returned final states).  Lazy-side laws here; the Lazy ≡
    Future bit-equality across the schedule zoo (including feedback
    chains) runs in the multidevice battery.
    """

    @staticmethod
    def _const_cell(const, state, item):
        return state + 1, jnp.tanh(item * const) + state * 0.01

    @staticmethod
    def _folded_cell(state, item):
        new = {"count": state["count"] + 1, "scale": state["scale"]}
        return new, jnp.tanh(item * state["scale"]) + state["count"] * 0.01

    def _w(self, n=4):
        return jnp.arange(n, dtype=jnp.float32)

    def _scale(self, n=4):
        return jnp.linspace(1.0, 2.0, n)

    def test_const_equals_folded_state(self):
        items = _items()
        a = (
            Stream.source(items)
            .through(self._const_cell, self._w(), const_state=self._scale())
            .collect()
        )
        b = (
            Stream.source(items)
            .through(
                self._folded_cell,
                {"count": self._w(), "scale": self._scale()},
            )
            .collect()
        )
        np.testing.assert_array_equal(np.asarray(a.items), np.asarray(b.items))
        # final states cover the mutable half only
        np.testing.assert_array_equal(
            np.asarray(a.states[0]), np.asarray(b.states[0]["count"])
        )

    def test_const_leading_axis_validated(self):
        with pytest.raises(ValueError, match="const_state"):
            Stream.source(_items()).through(
                self._const_cell, self._w(4), const_state=self._scale(3)
            )

    def test_const_under_feedback(self):
        emit = lambda x: x * 0.9 + 0.1
        init = _items(3)
        a = (
            Stream.feedback(init, 11, emit)
            .through(self._const_cell, self._w(), const_state=self._scale())
            .collect()
        )
        b = (
            Stream.feedback(init, 11, emit)
            .through(
                self._folded_cell,
                {"count": self._w(), "scale": self._scale()},
            )
            .collect()
        )
        np.testing.assert_array_equal(np.asarray(a.items), np.asarray(b.items))

    def test_const_multi_segment_with_mid_map(self):
        """Unified multi-segment machinery: a const segment composed with
        a const-free one through a fused mid-spine map (the pre_fn path),
        against the same program with const folded into mutable state."""
        items = _items()
        plain = lambda s, x: (s, jnp.tanh(x * s))
        w2 = jnp.linspace(0.5, 1.5, 3)
        a = (
            Stream.source(items)
            .through(self._const_cell, self._w(), const_state=self._scale())
            .map(lambda x: x * 0.5)
            .through(plain, w2, mutable_state=False)
            .collect()
        )
        b = (
            Stream.source(items)
            .through(
                self._folded_cell,
                {"count": self._w(), "scale": self._scale()},
            )
            .map(lambda x: x * 0.5)
            .through(plain, w2, mutable_state=False)
            .collect()
        )
        np.testing.assert_array_equal(np.asarray(a.items), np.asarray(b.items))
        assert len(a.states) == 2

    def test_const_never_returned_or_mutated(self):
        """A cell trying to 'write' const has nowhere to put it: the
        returned state structure is the mutable half, and collect's
        states match it."""
        items = _items()
        res = (
            Stream.source(items)
            .through(self._const_cell, self._w(), const_state=self._scale())
            .collect()
        )
        assert len(res.states) == 1
        assert np.asarray(res.states[0]).shape == (4,)


class TestBenchCheckGate:
    """Satellite: the --check regression gate's pure diff logic."""

    def _rec(self, schedule="gpipe", m=4, seconds=1.0):
        return {
            "schedule": schedule,
            "devices": 4,
            "interleave": 1,
            "virtual_stages": 4,
            "num_microbatches": m,
            "dim": 256,
            "rows": 4096,
            "measured_seconds": seconds,
            "modeled_bubble": 0.1,
            "modeled_ticks": 10,
        }

    def test_no_regression_within_tolerance(self):
        from benchmarks.run import check_regressions

        base = [self._rec(seconds=1.0)]
        fresh = [self._rec(seconds=1.05)]
        assert check_regressions(base, fresh, 0.10) == []

    def test_regression_detected(self):
        from benchmarks.run import check_regressions

        base = [self._rec(seconds=1.0), self._rec(m=8, seconds=2.0)]
        fresh = [self._rec(seconds=1.25), self._rec(m=8, seconds=2.05)]
        out = check_regressions(base, fresh, 0.10)
        assert len(out) == 1
        assert out[0]["num_microbatches"] == 4
        assert out[0]["ratio"] == pytest.approx(1.25)

    def test_size_mismatch_not_compared(self):
        from benchmarks.run import check_regressions

        base = [self._rec(seconds=1.0)]
        fresh = [dict(self._rec(seconds=9.0), dim=512)]
        assert check_regressions(base, fresh, 0.10) == []

    def test_missing_baseline_message_not_keyerror(self, tmp_path, capsys):
        from benchmarks.run import _load_baseline

        assert _load_baseline("serve", str(tmp_path / "nope.json")) is None
        err = capsys.readouterr().err
        assert "--suite serve" in err and "no baseline" in err

    def test_baseline_without_sweep_key_is_explained(self, tmp_path, capsys):
        import json as _json

        from benchmarks.run import _load_baseline

        p = tmp_path / "BENCH_serve.json"
        p.write_text(_json.dumps({"rows": []}))
        assert _load_baseline("serve", str(p)) is None
        assert "'sweep'" in capsys.readouterr().err

    def test_corrupt_baseline_is_explained(self, tmp_path, capsys):
        from benchmarks.run import _load_baseline

        p = tmp_path / "BENCH_serve.json"
        p.write_text("not json")
        assert _load_baseline("serve", str(p)) is None
        assert "unreadable" in capsys.readouterr().err

    def test_check_rejects_unknown_suite(self, capsys):
        from benchmarks.run import run_check

        assert run_check(0.1, False, only="nosuch") == 2
        assert "no gate for suite" in capsys.readouterr().err
