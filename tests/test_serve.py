"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def small_model():
    rng = jax.random.PRNGKey(0)
    sc = smoke_config(get_config("olmo-1b"))
    params = init_params(rng, T.model_layout(sc))
    return sc, params


def greedy_ref(params, sc, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        lg, _, _ = T.forward(params, sc, tokens=jnp.asarray([toks]), attn_impl="dense")
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


class TestEngine:
    def test_greedy_matches_full_forward(self, small_model):
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=3, max_len=64, prefill_chunk=4, max_new_tokens=5))
        prompts = [np.array([5, 9, 2, 7, 11]), np.array([3, 1, 4]), np.array([2] * 6)]
        reqs = [eng.submit(p) for p in prompts]
        done = eng.run_until_drained()
        assert len(done) == 3
        for req, p in zip(reqs, prompts):
            assert req.out_tokens == greedy_ref(params, sc, p, 5)

    def test_more_requests_than_slots(self, small_model):
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=3))
        prompts = [np.array([i + 1, i + 2, i + 3]) for i in range(5)]
        reqs = [eng.submit(p) for p in prompts]
        eng.run_until_drained()
        for req, p in zip(reqs, prompts):
            assert req.done
            assert req.out_tokens == greedy_ref(params, sc, p, 3)

    def test_staggered_arrivals(self, small_model):
        """Requests admitted mid-decode must not disturb running slots."""
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=6))
        r1 = eng.submit(np.array([5, 9, 2]))
        eng.step(); eng.step()
        r2 = eng.submit(np.array([7, 7]))
        eng.run_until_drained()
        assert r1.out_tokens == greedy_ref(params, sc, np.array([5, 9, 2]), 6)
        assert r2.out_tokens == greedy_ref(params, sc, np.array([7, 7]), 6)

    def test_request_isolation(self, small_model):
        """A request's output must not depend on its batch-mates."""
        sc, params = small_model
        solo = Engine(params, sc, ServeConfig(
            max_batch=1, max_len=64, prefill_chunk=4, max_new_tokens=4))
        rs = solo.submit(np.array([9, 4, 1]))
        solo.run_until_drained()
        batched = Engine(params, sc, ServeConfig(
            max_batch=4, max_len=64, prefill_chunk=4, max_new_tokens=4))
        rb = batched.submit(np.array([9, 4, 1]))
        for other in ([3, 3, 3], [8], [2, 6, 4, 4, 2]):
            batched.submit(np.array(other))
        batched.run_until_drained()
        assert rs.out_tokens == rb.out_tokens
