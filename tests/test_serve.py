"""Serving engines: continuous batching correctness.

Single-device: the sequential reference ``Engine`` against a full
forward, retirement edge cases, per-request RNG, and the
``StreamEngine`` (LazyEvaluator — the same Stream.feedback round
program, layer-sequential).  The pipelined FutureEvaluator bit-identity
gate runs in test_serve_pipeline.py (multidevice marker).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DecodePipelineConfig
from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import Engine, Request, ServeConfig, StreamEngine


@pytest.fixture(scope="module")
def small_model():
    rng = jax.random.PRNGKey(0)
    sc = smoke_config(get_config("olmo-1b"))
    params = init_params(rng, T.model_layout(sc))
    return sc, params


@pytest.fixture(scope="module")
def cell_model():
    """4 layer groups so the decode chain splits into cells."""
    rng = jax.random.PRNGKey(0)
    sc = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=4)
    params = init_params(rng, T.model_layout(sc))
    return sc, params


def greedy_ref(params, sc, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        lg, _, _ = T.forward(params, sc, tokens=jnp.asarray([toks]), attn_impl="dense")
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


class TestEngine:
    def test_greedy_matches_full_forward(self, small_model):
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=3, max_len=64, prefill_chunk=4, max_new_tokens=5))
        prompts = [np.array([5, 9, 2, 7, 11]), np.array([3, 1, 4]), np.array([2] * 6)]
        reqs = [eng.submit(p) for p in prompts]
        done = eng.run_until_drained()
        assert len(done) == 3
        for req, p in zip(reqs, prompts):
            assert req.out_tokens == greedy_ref(params, sc, p, 5)

    def test_more_requests_than_slots(self, small_model):
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=3))
        prompts = [np.array([i + 1, i + 2, i + 3]) for i in range(5)]
        reqs = [eng.submit(p) for p in prompts]
        eng.run_until_drained()
        for req, p in zip(reqs, prompts):
            assert req.done
            assert req.out_tokens == greedy_ref(params, sc, p, 3)

    def test_staggered_arrivals(self, small_model):
        """Requests admitted mid-decode must not disturb running slots."""
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=6))
        r1 = eng.submit(np.array([5, 9, 2]))
        eng.step(); eng.step()
        r2 = eng.submit(np.array([7, 7]))
        eng.run_until_drained()
        assert r1.out_tokens == greedy_ref(params, sc, np.array([5, 9, 2]), 6)
        assert r2.out_tokens == greedy_ref(params, sc, np.array([7, 7]), 6)

    def test_request_isolation(self, small_model):
        """A request's output must not depend on its batch-mates."""
        sc, params = small_model
        solo = Engine(params, sc, ServeConfig(
            max_batch=1, max_len=64, prefill_chunk=4, max_new_tokens=4))
        rs = solo.submit(np.array([9, 4, 1]))
        solo.run_until_drained()
        batched = Engine(params, sc, ServeConfig(
            max_batch=4, max_len=64, prefill_chunk=4, max_new_tokens=4))
        rb = batched.submit(np.array([9, 4, 1]))
        for other in ([3, 3, 3], [8], [2, 6, 4, 4, 2]):
            batched.submit(np.array(other))
        batched.run_until_drained()
        assert rs.out_tokens == rb.out_tokens


class TestRetirementEdges:
    def _first_token(self, params, sc, prompt):
        lg, _, _ = T.forward(params, sc, tokens=jnp.asarray([prompt]),
                             attn_impl="dense")
        return int(jnp.argmax(lg[0, -1]))

    def test_max_new_tokens_one(self, small_model):
        """A budget of 1 completes on the prefill-sampled token alone."""
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=1))
        req = eng.submit(np.array([5, 9, 2]))
        done = eng.run_until_drained()
        assert req.done and req in done
        assert len(req.out_tokens) == 1
        assert req.out_tokens == greedy_ref(params, sc, np.array([5, 9, 2]), 1)
        # its slot was never occupied
        assert all(r is None for r in eng.active)

    def test_eos_on_prefill_token(self, small_model):
        """EOS hit by the first (prefill-sampled) token retires at once."""
        sc, params = small_model
        prompt = np.array([5, 9, 2, 7])
        eos = self._first_token(params, sc, prompt)
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=8,
            eos_id=eos))
        req = eng.submit(prompt)
        other = eng.submit(np.array([3, 1]))
        eng.run_until_drained()
        assert req.done and req.out_tokens == [eos]
        assert other.done  # the freed slot kept serving

    def test_max_len_boundary_no_oob_cache_write(self, small_model):
        """No cache row at index >= max_len is ever written: lengths
        stays < max_len and the boundary slot retires exactly there."""
        sc, params = small_model
        max_len = 16
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=max_len, prefill_chunk=4,
            max_new_tokens=64))
        near = eng.submit(np.arange(1, max_len - 2, dtype=np.int32))  # plen=13
        long_lived = eng.submit(np.array([2, 3]))
        steps = 0
        while (eng.queue or any(r is not None for r in eng.active)) and steps < 80:
            eng.step()
            steps += 1
            assert int(eng.lengths.max()) <= max_len - 1
        assert near.done
        # retired at the boundary: plen + generated == max_len - 1 context
        # rows used, never one past the cache
        assert len(near.out_tokens) < 64
        assert long_lived.done

    def test_prompt_at_max_len_rejected(self, small_model):
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(max_batch=1, max_len=8))
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(np.arange(8, dtype=np.int32))

    def test_ragged_tail_near_cache_end(self, small_model):
        """max_len not a multiple of prefill_chunk: the padded tail
        chunk must clamp to the cache end — an unclamped chunk's
        dynamic_update_slice would shift backwards and silently corrupt
        earlier prompt rows."""
        sc, params = small_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=1, max_len=20, prefill_chunk=16, max_new_tokens=2))
        prompt = np.arange(1, 18, dtype=np.int32)  # plen=17: tail at 16..19
        req = eng.submit(prompt)
        eng.run_until_drained()
        assert req.out_tokens == greedy_ref(params, sc, prompt, 2)


class TestPerRequestRNG:
    def test_sampling_independent_of_admission_order(self, small_model):
        """Temperature sampling derives from (seed, uid, token index):
        the same request samples identically solo or batched, early or
        late in the queue."""
        sc, params = small_model
        mk = lambda b: Engine(params, sc, ServeConfig(
            max_batch=b, max_len=64, prefill_chunk=4, max_new_tokens=5,
            temperature=0.8, seed=3))
        solo = mk(1)
        r_solo = solo.submit(np.array([9, 4, 1]))
        solo.run_until_drained()
        # same uid (0) in a crowded engine, admitted alongside others
        crowded = mk(2)
        r_crowd = crowded.submit(np.array([9, 4, 1]))
        for other in ([3, 3, 3], [8], [2, 6, 4]):
            crowded.submit(np.array(other))
        crowded.run_until_drained()
        assert r_solo.out_tokens == r_crowd.out_tokens

    def test_retry_reproducible(self, small_model):
        sc, params = small_model
        outs = []
        for _ in range(2):
            eng = Engine(params, sc, ServeConfig(
                max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=6,
                temperature=1.1, seed=7))
            r = eng.submit(np.array([5, 9, 2]))
            eng.run_until_drained()
            outs.append(r.out_tokens)
        assert outs[0] == outs[1]


class TestStreamEngineLazy:
    """The Stream.feedback round program (LazyEvaluator) must match the
    sequential engine token for token — same retirement, same mid-flight
    admissions, same sampling."""

    def _workload(self):
        prompts = [np.array([5, 9, 2, 7, 11]), np.array([3, 1, 4]),
                   np.array([2] * 6), np.array([8, 8]),
                   np.array([1, 2, 3, 4]), np.array([7])]
        budgets = [6, 3, 5, 1, 6, 4]
        return prompts, budgets

    @pytest.mark.parametrize("microbatches,round_steps", [(2, 4), (4, 3)])
    def test_matches_sequential(self, cell_model, microbatches, round_steps):
        sc, params = cell_model
        scfg = ServeConfig(max_batch=4, max_len=64, prefill_chunk=4,
                           max_new_tokens=6)
        prompts, budgets = self._workload()
        ref = Engine(params, sc, scfg)
        reqs_a = [ref.submit(p, b) for p, b in zip(prompts, budgets)]
        ref.run_until_drained()
        pcfg = DecodePipelineConfig(
            num_cells=4, microbatches=microbatches,
            round_steps=round_steps, admit_per_round=3)
        eng = StreamEngine(params, sc, scfg, pcfg)
        reqs_b = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
        done = eng.run_until_drained()
        assert len(done) == len(prompts)
        for ra, rb in zip(reqs_a, reqs_b):
            assert rb.done
            assert ra.out_tokens == rb.out_tokens

    def test_temperature_matches_sequential(self, cell_model):
        sc, params = cell_model
        scfg = ServeConfig(max_batch=2, max_len=64, prefill_chunk=4,
                           max_new_tokens=5, temperature=0.9, seed=11)
        prompts = [np.array([5, 9, 2]), np.array([4, 4]), np.array([1, 2, 3])]
        ref = Engine(params, sc, scfg)
        reqs_a = [ref.submit(p) for p in prompts]
        ref.run_until_drained()
        eng = StreamEngine(params, sc, scfg, DecodePipelineConfig(
            num_cells=2, microbatches=2, round_steps=3, admit_per_round=2))
        reqs_b = [eng.submit(p) for p in prompts]
        eng.run_until_drained()
        for ra, rb in zip(reqs_a, reqs_b):
            assert ra.out_tokens == rb.out_tokens

    def test_no_oob_cache_write_at_boundary(self, cell_model):
        sc, params = cell_model
        max_len = 16
        scfg = ServeConfig(max_batch=2, max_len=max_len, prefill_chunk=4,
                           max_new_tokens=64)
        eng = StreamEngine(params, sc, scfg, DecodePipelineConfig(
            num_cells=2, microbatches=2, round_steps=4, admit_per_round=2))
        near = eng.submit(np.arange(1, max_len - 2, dtype=np.int32))
        eng.submit(np.array([2, 3]))
        rounds = 0
        while (eng.queue or any(r is not None for r in eng.active)) and rounds < 40:
            eng.step()
            rounds += 1
            assert int(eng.lengths.max()) <= max_len - 1
        assert near.done


class TestStreamEnginePallas:
    """``kernels="pallas"`` (interpret-emulated on CPU) must be bitwise
    token-identical to the xla sequential engine: the fused decode
    attention replaces the per-layer slab update + dense read, and the
    fused emit epilogue replaces final-norm + logits.  The arch axis
    covers layernorm+tied (olmo), rmsnorm+untied hybrid attn/ssm
    (jamba), and attention-free rmsnorm+tied (mamba2 — emit fusion
    only)."""

    ARCHS = ["olmo-1b", "jamba-1.5-large-398b", "mamba2-1.3b"]

    def _run_pair(self, arch, temperature=0.0):
        sc = smoke_config(get_config(arch))
        params = init_params(jax.random.PRNGKey(0), T.model_layout(sc))
        scfg = ServeConfig(max_batch=4, max_len=32, prefill_chunk=4,
                           max_new_tokens=5, temperature=temperature, seed=3)
        prompts = [np.array([5, 9, 2, 7]), np.array([3, 1]),
                   np.array([2] * 5), np.array([8, 8, 4]), np.array([6])]
        budgets = [5, 3, 4, 5, 2]
        ref = Engine(params, sc, scfg)
        reqs_a = [ref.submit(p, b) for p, b in zip(prompts, budgets)]
        ref.run_until_drained()
        pcfg = DecodePipelineConfig(num_cells=2, microbatches=2,
                                    round_steps=3, admit_per_round=2,
                                    kernels="pallas")
        eng = StreamEngine(params, sc, scfg, pcfg)
        assert eng.kernels == "pallas"
        reqs_b = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
        eng.run_until_drained()
        for ra, rb in zip(reqs_a, reqs_b):
            assert rb.done
            assert ra.out_tokens == rb.out_tokens

    @pytest.mark.parametrize("arch", ARCHS)
    def test_greedy_bitwise_vs_xla_sequential(self, arch):
        self._run_pair(arch)

    def test_temperature_bitwise_vs_xla_sequential(self):
        self._run_pair("olmo-1b", temperature=0.9)

    def test_arch_knob_inherited_when_pipeline_unset(self, cell_model):
        """DecodePipelineConfig.kernels=None defers to ArchConfig.kernels."""
        sc, params = cell_model
        scfg = ServeConfig(max_batch=2, max_len=32, prefill_chunk=4,
                           max_new_tokens=3)
        eng = StreamEngine(
            params, sc.with_overrides(kernels="pallas"), scfg,
            DecodePipelineConfig(num_cells=2, microbatches=2,
                                 round_steps=2, admit_per_round=1))
        assert eng.kernels == "pallas"
        r = eng.submit(np.array([5, 9, 2]))
        eng.run_until_drained()
        assert r.done and len(r.out_tokens) == 3


class TestServeBenchGate:
    """The BENCH_serve.json regression gate is throughput-directional."""

    def _rec(self, engine="stream_gpipe", batch=8, tok_s=100.0):
        return {
            "engine": engine, "schedule": "gpipe", "devices": 2,
            "interleave": 1, "batch": batch, "dim": 256, "max_new": 24,
            "tokens_per_sec": tok_s,
        }

    def test_within_tolerance_passes(self):
        from benchmarks.run import check_serve_regressions

        base = [self._rec(tok_s=100.0)]
        fresh = [self._rec(tok_s=95.0)]
        assert check_serve_regressions(base, fresh, 0.10) == []

    def test_throughput_drop_detected(self):
        from benchmarks.run import check_serve_regressions

        base = [self._rec(tok_s=100.0), self._rec(batch=16, tok_s=200.0)]
        fresh = [self._rec(tok_s=80.0), self._rec(batch=16, tok_s=195.0)]
        out = check_serve_regressions(base, fresh, 0.10)
        assert len(out) == 1 and out[0]["batch"] == 8

    def test_faster_never_flags(self):
        from benchmarks.run import check_serve_regressions

        base = [self._rec(tok_s=100.0)]
        fresh = [self._rec(tok_s=150.0)]
        assert check_serve_regressions(base, fresh, 0.10) == []

    def test_kernels_axis_distinct_cells(self):
        """pallas cells never gate against xla cells; records written
        before the kernels axis existed keep gating the xla cells."""
        from benchmarks.run import check_serve_regressions

        legacy = [self._rec(tok_s=100.0)]  # pre-axis baseline: no key
        pallas = [dict(self._rec(tok_s=10.0), kernels="pallas")]
        assert check_serve_regressions(legacy, pallas, 0.10) == []
        xla = [dict(self._rec(tok_s=80.0), kernels="xla")]
        out = check_serve_regressions(legacy, xla, 0.10)
        assert len(out) == 1 and out[0]["batch"] == 8

    def _chaos_rec(self, lost=0, bitwise=True):
        return {
            "engine": "chaos_sequential", "schedule": "-", "devices": 1,
            "interleave": 1, "batch": 8, "dim": 0,
            "requests_lost": lost, "bitwise_equal": bitwise,
            "recovery_overhead_seconds": 0.1,
        }

    def test_chaos_zero_loss_passes(self):
        from benchmarks.run import check_serve_regressions

        assert check_serve_regressions([], [self._chaos_rec()], 0.10) == []

    def test_chaos_lost_request_flags_without_baseline(self):
        """The chaos invariant is absolute — it fires on the fresh run
        alone, with no matching baseline cell required."""
        from benchmarks.run import check_serve_regressions

        out = check_serve_regressions([], [self._chaos_rec(lost=2)], 0.10)
        assert len(out) == 1 and out[0]["requests_lost"] == 2

    def test_chaos_bitwise_mismatch_flags(self):
        from benchmarks.run import check_serve_regressions

        out = check_serve_regressions(
            [], [self._chaos_rec(bitwise=False)], 0.10)
        assert len(out) == 1 and out[0]["bitwise_equal"] is False

    def test_chaos_cells_skip_throughput_gate(self):
        """Chaos cells carry no tokens_per_sec, so they never trip the
        throughput comparator even when a baseline chaos cell exists."""
        from benchmarks.run import check_serve_regressions

        assert check_serve_regressions(
            [self._chaos_rec()], [self._chaos_rec()], 0.10) == []
