"""Mathematical properties of the shared layers (hypothesis)."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    apply_rope,
    attention_dense,
    layernorm_nonparam,
    rmsnorm,
)


class TestRoPE:
    @hypothesis.given(st.integers(0, 500), st.integers(1, 8))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_norm_preserving(self, pos, heads):
        """Rotations preserve the per-head L2 norm."""
        rng = np.random.default_rng(pos)
        x = jnp.asarray(rng.normal(size=(1, 3, heads, 64)), jnp.float32)
        positions = jnp.full((1, 3), pos)
        y = apply_rope(x, positions, theta=1e4)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )

    def test_relative_phase(self):
        """<rope(q,m), rope(k,n)> depends only on m-n (the RoPE property)."""
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)

        def dot_at(m, n):
            qm = apply_rope(q, jnp.full((1, 1), m), theta=1e4)
            kn = apply_rope(k, jnp.full((1, 1), n), theta=1e4)
            return float(jnp.sum(qm * kn))

        assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
        assert dot_at(7, 0) == pytest.approx(dot_at(57, 50), rel=1e-4)

    def test_position_zero_is_identity(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(2, 4, 2, 16)), jnp.float32)
        y = apply_rope(x, jnp.zeros((2, 4), jnp.int32), theta=1e4)
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


class TestNorms:
    @hypothesis.given(st.integers(0, 100))
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_rmsnorm_unit_rms(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(4, 64)) * rng.uniform(0.1, 10), jnp.float32)
        y = rmsnorm({"scale": jnp.ones(64)}, x, eps=1e-6)
        rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_rmsnorm_scale_invariance(self):
        """rmsnorm(c·x) == rmsnorm(x) for c > 0."""
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(3, 32)), jnp.float32)
        p = {"scale": jnp.ones(32)}
        # equality is exact only as eps -> 0; tolerance covers eps=1e-5
        np.testing.assert_allclose(
            np.asarray(rmsnorm(p, x)), np.asarray(rmsnorm(p, 7.3 * x)),
            atol=2e-4,
        )

    def test_layernorm_zero_mean_unit_var(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(5, 128)) * 4 + 2, jnp.float32)
        y = np.asarray(layernorm_nonparam(x, eps=1e-6))
        np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
        np.testing.assert_allclose(y.var(-1), 1.0, rtol=1e-3)


class TestAttentionProperties:
    def test_permutation_equivariance_over_batch(self):
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(3, 8, 4, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(3, 8, 2, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(3, 8, 2, 16)), jnp.float32)
        out = attention_dense(q, k, v, causal=True)
        perm = jnp.asarray([2, 0, 1])
        out_p = attention_dense(q[perm], k[perm], v[perm], causal=True)
        np.testing.assert_allclose(
            np.asarray(out[perm]), np.asarray(out_p), atol=1e-6
        )

    def test_causal_prefix_independence(self):
        """Outputs at position t must not change when the suffix changes."""
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
        out = attention_dense(q, k, v, causal=True)
        k2 = k.at[:, 5:].set(0.0)
        v2 = v.at[:, 5:].set(99.0)
        out2 = attention_dense(q, k2, v2, causal=True)
        np.testing.assert_allclose(
            np.asarray(out[:, :5]), np.asarray(out2[:, :5]), atol=1e-6
        )

    def test_uniform_values_pass_through(self):
        """If V is constant, attention output equals that constant."""
        rng = np.random.default_rng(6)
        q = jnp.asarray(rng.normal(size=(1, 6, 2, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 6, 2, 8)), jnp.float32)
        v = jnp.ones((1, 6, 2, 8), jnp.float32) * 3.25
        out = attention_dense(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-5)


class TestEvaluatorProperty:
    @hypothesis.given(
        st.integers(1, 4), st.integers(1, 6), st.integers(0, 1000)
    )
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_lazy_matches_python_fold(self, cells, items, seed):
        """For arbitrary affine cells, the evaluator == a python fold."""
        from repro.core import LazyEvaluator, StreamProgram, evaluate

        rng = np.random.default_rng(seed)
        scales = rng.uniform(0.5, 1.5, size=cells).astype(np.float32)

        def cell(state, item):
            return state, item * state

        prog = StreamProgram(cell, jnp.asarray(scales), cells)
        xs = rng.normal(size=(items, 2)).astype(np.float32)
        _, outs = evaluate(prog, jnp.asarray(xs), LazyEvaluator())
        expect = xs * np.prod(scales)
        np.testing.assert_allclose(np.asarray(outs), expect, rtol=1e-5)
