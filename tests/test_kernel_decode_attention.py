"""Fused decode-path kernels vs pure-jnp oracles — bitwise, interpret mode.

The serving hot path dispatches two fused Pallas ops (see
``repro.kernels``): ``decode_attention`` (KV row scatter + single-row
attention read, no updated slab materialized in HBM) and
``emit_norm_logits`` (final-norm + logits head).  Both are gated on
*bitwise* equality with their pure-jnp refs — the refs are verbatim the
unfused model ops — so ``kernels="pallas"`` serving is token-identical
to ``kernels="xla"`` by construction.  Also covers the dispatch
registry and the training-path rejection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import KERNEL_MODES, get_impl, resolve_mode
from repro.kernels.decode_attention.ops import fused_decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.emit_norm_logits.ops import emit_norm_logits
from repro.kernels.emit_norm_logits.ref import emit_norm_logits_ref


def _bitwise(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype and a.shape == b.shape
    return bool((a == b).all())


def _assert_matches(out, ref, dtype):
    """bf16 (the serving dtype): bitwise — the fp32 intermediate math is
    identical op for op and both paths round through the same bf16 cast.
    fp32: a few ULPs — XLA's CPU gemm/softmax reduction blocking differs
    between the batched ref einsum and the kernel's per-row einsum for
    some shapes, so exact fp32 bit equality would be shape-dependent."""
    if dtype == jnp.bfloat16:
        assert _bitwise(out, ref)
    else:
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)


def _decode_case(rng, b, s, h, kv, dh, dtype, pos):
    q = jnp.asarray(rng.normal(size=(b, 1, h, dh)), dtype)
    k_new = jnp.asarray(rng.normal(size=(b, kv, dh)), dtype)
    v_new = jnp.asarray(rng.normal(size=(b, kv, dh)), dtype)
    k_cache = jnp.asarray(rng.normal(size=(b, s, kv, dh)), dtype)
    v_cache = jnp.asarray(rng.normal(size=(b, s, kv, dh)), dtype)
    pos = jnp.asarray(pos, jnp.int32)
    kv_len = pos + 1
    return q, k_new, v_new, k_cache, v_cache, pos, kv_len


class TestDecodeAttentionKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
    def test_ragged_positions_bitwise(self, dtype):
        """Every row at a different depth — the steady decode tick."""
        rng = np.random.default_rng(0)
        b, s, h, kv, dh = 4, 16, 4, 2, 16
        pos = np.array([0, 5, 11, 15])  # includes fresh row and boundary
        q, kn, vn, kc, vc, pos, kvl = _decode_case(rng, b, s, h, kv, dh, dtype, pos)
        out = fused_decode_attention(
            q, kn, vn, kc, vc, pos=pos, kv_len=kvl, interpret=True)
        ref = decode_attention_ref(q, kn, vn, kc, vc, pos=pos, kv_len=kvl)
        assert out.shape == ref.shape == (b, 1, h, dh)
        _assert_matches(out, ref, dtype)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
    def test_max_len_boundary(self, dtype):
        """All rows writing the last cache slot (pos == max_len - 1)."""
        rng = np.random.default_rng(1)
        b, s, h, kv, dh = 3, 8, 2, 2, 8
        q, kn, vn, kc, vc, pos, kvl = _decode_case(
            rng, b, s, h, kv, dh, dtype, np.full(3, s - 1))
        out = fused_decode_attention(
            q, kn, vn, kc, vc, pos=pos, kv_len=kvl, interpret=True)
        ref = decode_attention_ref(q, kn, vn, kc, vc, pos=pos, kv_len=kvl)
        _assert_matches(out, ref, dtype)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
    def test_admission_rows(self, dtype):
        """Mid-round admissions: freshly prefilled rows (pos=0, garbage
        cache beyond the valid prefix) mixed with deep rows — the mask
        must come from kv_len, never from cache contents."""
        rng = np.random.default_rng(2)
        b, s, h, kv, dh = 4, 12, 4, 4, 16
        q, kn, vn, kc, vc, pos, kvl = _decode_case(
            rng, b, s, h, kv, dh, dtype, np.array([0, 9, 0, 3]))
        # poison the invalid region of the fresh rows
        kc = kc.at[0, 1:].set(jnp.asarray(1e4, dtype))
        vc = vc.at[0, 1:].set(jnp.asarray(1e4, dtype))
        out = fused_decode_attention(
            q, kn, vn, kc, vc, pos=pos, kv_len=kvl, interpret=True)
        ref = decode_attention_ref(q, kn, vn, kc, vc, pos=pos, kv_len=kvl)
        _assert_matches(out, ref, dtype)
        assert bool(jnp.isfinite(out.astype(jnp.float32)).all())

    def test_under_jit_matches_eager_ref(self):
        """The engine calls the kernel from inside a jitted round body."""
        rng = np.random.default_rng(3)
        b, s, h, kv, dh = 2, 8, 2, 1, 8
        q, kn, vn, kc, vc, pos, kvl = _decode_case(
            rng, b, s, h, kv, dh, jnp.bfloat16, np.array([2, 7]))
        out = jax.jit(
            lambda *a: fused_decode_attention(
                *a[:5], pos=a[5], kv_len=a[6], interpret=True)
        )(q, kn, vn, kc, vc, pos, kvl)
        ref = decode_attention_ref(q, kn, vn, kc, vc, pos=pos, kv_len=kvl)
        assert _bitwise(out, ref)


EMIT_CASES = [
    # norm, tied, dtype
    ("rmsnorm", False, jnp.float32),
    ("rmsnorm", False, jnp.bfloat16),
    ("rmsnorm", True, jnp.bfloat16),
    ("layernorm_nonparam", True, jnp.float32),
    ("layernorm_nonparam", True, jnp.bfloat16),
    ("layernorm_nonparam", False, jnp.bfloat16),
]


class TestEmitNormLogitsKernel:
    @pytest.mark.parametrize("norm,tied,dtype", EMIT_CASES, ids=str)
    def test_bitwise_vs_ref(self, norm, tied, dtype):
        rng = np.random.default_rng(4)
        b, d, v = 3, 32, 96  # v not a multiple of 512: block_v walks down
        x = jnp.asarray(rng.normal(size=(b, 1, d)), dtype)
        w = jnp.asarray(
            rng.normal(size=(v, d) if tied else (d, v)) * 0.1, dtype)
        scale = (jnp.asarray(rng.normal(size=(d,)) * 0.2 + 1.0, dtype)
                 if norm == "rmsnorm" else None)
        out = emit_norm_logits(
            x, w, norm=norm, scale=scale, tied=tied, interpret=True)
        ref = emit_norm_logits_ref(x, w, norm=norm, scale=scale, tied=tied)
        assert out.dtype == jnp.float32 and out.shape == (b, v)
        assert _bitwise(out, ref)

    def test_bitwise_vs_jitted_ref_bf16(self):
        """The hard case: under jit, XLA elides the f32->bf16->f32
        round-trip only for directly-chained dot->convert.  The kernel
        keeps the dot in input dtype and upcasts outside the pallas
        call, so it matches the ref both eager and jitted."""
        rng = np.random.default_rng(5)
        b, d, v = 2, 64, 128
        x = jnp.asarray(rng.normal(size=(b, 1, d)), jnp.bfloat16)
        w = jnp.asarray(rng.normal(size=(d, v)) * 0.1, jnp.bfloat16)
        scale = jnp.asarray(rng.normal(size=(d,)) * 0.2 + 1.0, jnp.bfloat16)
        kw = dict(norm="rmsnorm", scale=scale, tied=False)
        out = jax.jit(
            lambda a, b_: emit_norm_logits(a, b_, interpret=True, **kw)
        )(x, w)
        ref_eager = emit_norm_logits_ref(x, w, **kw)
        ref_jit = jax.jit(lambda a, b_: emit_norm_logits_ref(a, b_, **kw))(x, w)
        assert _bitwise(out, ref_eager)
        assert _bitwise(out, ref_jit)

    def test_bad_norm_rejected(self):
        x = jnp.zeros((1, 1, 8)); w = jnp.zeros((8, 16))
        with pytest.raises(ValueError):
            emit_norm_logits(x, w, norm="batchnorm")


class TestKernelRegistry:
    def test_resolve_mode(self):
        assert resolve_mode(None) == "xla"
        assert resolve_mode("xla") == "xla"
        assert resolve_mode("pallas") == "pallas"
        assert resolve_mode("auto") in ("xla", "pallas")
        with pytest.raises(ValueError):
            resolve_mode("cuda")

    def test_get_impl_dispatch(self):
        assert get_impl("decode_attention", "xla") is decode_attention_ref
        assert get_impl("decode_attention", "pallas") is fused_decode_attention
        assert get_impl("emit_norm_logits", "xla") is emit_norm_logits_ref
        assert get_impl("emit_norm_logits", "pallas") is emit_norm_logits
        with pytest.raises(ValueError):
            get_impl("decode_attention", "cuda")
        with pytest.raises(ValueError):
            get_impl("conv3d", "xla")

    def test_legacy_ops_exported(self):
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.rmsnorm.ops import rmsnorm
        from repro.kernels.ssd.ops import ssd_chunked_pallas

        assert get_impl("attention", "pallas") is flash_attention
        assert get_impl("rmsnorm", "pallas") is rmsnorm
        assert get_impl("ssd", "pallas") is ssd_chunked_pallas
        for op in ("attention", "rmsnorm", "ssd"):
            assert callable(get_impl(op, "xla"))

    def test_train_step_rejects_pallas(self):
        from repro.configs.registry import get_config, smoke_config
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import TrainConfig, make_train_step

        cfg = smoke_config(get_config("olmo-1b"))
        ocfg = AdamWConfig(learning_rate=1e-3, warmup_steps=1, total_steps=2)
        with pytest.raises(ValueError, match="no VJPs"):
            make_train_step(cfg, TrainConfig(kernels="pallas"), ocfg)
        with pytest.raises(ValueError, match="planned"):
            make_train_step(
                cfg,
                TrainConfig(kernels="pallas", pipeline_backward="planned"),
                ocfg,
            )
        # auto resolves to xla off-TPU: accepted
        make_train_step(cfg, TrainConfig(kernels="auto"), ocfg)
