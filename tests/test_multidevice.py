"""Multi-device behaviour (FutureEvaluator pipelining, sharded train step).

jax fixes the device count at first init, so these tests run a single
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4 that
executes a battery of checks and prints one line per check; the parent
asserts on the report.  (The 512-device flag stays local to dryrun.py.)
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidevice

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro import compat
from repro.core import (FutureEvaluator, LazyEvaluator, Stream, StreamProgram,
                        PipelineConfig, evaluate, pipeline_apply, split_stages)
from repro.algorithms import sieve, polynomial as poly

mesh = compat.make_mesh((4,), ("pod",), axis_types=(compat.AxisType.Auto,))
fut = FutureEvaluator(mesh, "pod")
ZOO = [("gpipe", 1), ("one_f_one_b", 1), ("interleaved", 2)]

# 1. evaluator equivalence with mutable state — full schedule zoo, and
# bit-identical (not just allclose): same cells, same order, same ops.
def cell(state, item):
    return state + 1, item * 1.001 + state
prog = StreamProgram(cell, jnp.arange(8, dtype=jnp.float32), 8)
items = jnp.linspace(0, 1, 18).reshape(6, 3)
sl, ol = evaluate(prog, items, LazyEvaluator())
ok = True
for name, v in ZOO:
    ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
    sf, of = evaluate(prog, items, ev)
    ok &= bool(jnp.all(sl == sf)) and bool(jnp.all(ol == of))
print("EQUIV", ok)

# 1b. ragged microbatch count (M=5 not divisible by D=4)
items5 = jnp.linspace(0, 1, 15).reshape(5, 3)
sl5, ol5 = evaluate(prog, items5, LazyEvaluator())
ok = True
for name, v in ZOO:
    ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
    sf5, of5 = evaluate(prog, items5, ev)
    ok &= bool(jnp.all(sl5 == sf5)) and bool(jnp.all(ol5 == of5))
print("EQUIV_RAGGED", ok)

# 2. gradient equivalence through the pipeline (GPipe by autodiff; 1F1B
# and interleaved reverse the same way)
W = jax.random.normal(jax.random.PRNGKey(0), (8, 3, 3))
def loss(W, ev):
    p = StreamProgram(lambda w, x: (w, jnp.tanh(x @ w)), W, 8,
                      mutable_state=False, remat=True)
    return jnp.sum(evaluate(p, items, ev)[1] ** 2)
g1 = jax.grad(lambda w: loss(w, LazyEvaluator()))(W)
ok = True
for name, v in ZOO:
    ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
    g2 = jax.grad(lambda w: loss(w, ev))(W)
    ok &= bool(jnp.allclose(g1, g2, atol=1e-5))
print("GRAD", ok)

# 2c. the planned (custom-VJP) backward: the combined plan's B units
# replayed over the reverse ring.  Gradients (weights AND items) must be
# bitwise-equal to jax.grad of the forward plan for gpipe and
# one_f_one_b — the true-1F1B acceptance gate.  Interleaved's scan
# transpose reassociates the weight-grad reduction (its per-microbatch
# contributions are bitwise equal; only the sum association differs),
# so it is held to allclose.
def loss_pb(w, it, ev):
    p = StreamProgram(lambda w_, x: (w_, jnp.tanh(x @ w_)), w, 8,
                      mutable_state=False, remat=True)
    return jnp.sum(evaluate(p, it, ev)[1] ** 2)
okb, okc, okf = True, True, True
prog_imm = StreamProgram(lambda w_, x: (w_, jnp.tanh(x @ w_)), W, 8,
                         mutable_state=False)
sl_i, ol_i = evaluate(prog_imm, items, LazyEvaluator())
for name, v in ZOO:
    eva = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
    evp = FutureEvaluator(mesh, "pod", schedule=name, interleave=v,
                          backward="planned")
    ga = jax.grad(loss_pb, argnums=(0, 1))(W, items, eva)
    gp = jax.grad(loss_pb, argnums=(0, 1))(W, items, evp)
    same = all(bool(jnp.all(a == b)) for a, b in zip(ga, gp))
    close = all(bool(jnp.allclose(a, b, atol=1e-5)) for a, b in zip(ga, gp))
    if name in ("gpipe", "one_f_one_b"):
        okb &= same
    okc &= close
    # the planned engine's forward stays bitwise-identical to Lazy
    sf_i, of_i = evaluate(prog_imm, items, evp)
    okf &= bool(jnp.all(ol_i == of_i)) and bool(jnp.all(sl_i == sf_i))
# multi-segment pin: the unified machinery threads integer bookkeeping
# through the state (float0 cotangents in the planned bwd) — a
# through -> map -> through chain must stay bitwise too
wa2, wb2 = jnp.arange(4, dtype=jnp.float32), jnp.linspace(0.5, 1.5, 4)
cellm = lambda w, x: (w, jnp.tanh(x * w))
def loss_ms(wa, wb, ev):
    s = (Stream.source(items).through(cellm, wa, mutable_state=False)
         .map(lambda x: x * 0.5)
         .through(cellm, wb, mutable_state=False))
    return jnp.sum(s.collect(ev).items ** 2)
gms_a = jax.grad(loss_ms, argnums=(0, 1))(
    wa2, wb2, FutureEvaluator(mesh, "pod", schedule="one_f_one_b"))
gms_p = jax.grad(loss_ms, argnums=(0, 1))(
    wa2, wb2,
    FutureEvaluator(mesh, "pod", schedule="one_f_one_b", backward="planned"))
okb &= all(bool(jnp.all(a == b)) for a, b in
           zip(jax.tree.leaves(gms_a), jax.tree.leaves(gms_p)))
print("PLANNED_GRAD_BITWISE", okb)
print("PLANNED_GRAD_CLOSE", okc)
print("PLANNED_FWD", okf)

# 2b. the output-collection psum is gone: no all-reduce in the lowered
# forward HLO (outputs leave the region stage-sharded, one slice at the
# boundary).  Params/program built eagerly so nothing but the engine is
# in the traced region.
W_hlo = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8))
prog_hlo = StreamProgram(lambda w, x: (w, jnp.tanh(x @ w)), W_hlo, 4,
                         mutable_state=False)
hlo = jax.jit(lambda it: evaluate(prog_hlo, it, fut)[1]).lower(
    jax.random.normal(jax.random.PRNGKey(1), (8, 4, 8))).compile().as_text()
print("NO_PSUM_COLLECT", "all-reduce" not in hlo)

# 3. pipeline_apply wrapper — every schedule matches the Lazy reference
stage_params = split_stages(jax.random.normal(jax.random.PRNGKey(1), (8, 4, 4)), 8, 4)
x = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
def stage_fn(p, xb):
    for i in range(p.shape[0]):
        xb = jnp.tanh(xb @ p[i])
    return xb
cfgp = PipelineConfig(num_stages=4, num_microbatches=4, axis_name="pod")
y_lazy = pipeline_apply(stage_fn, stage_params, x, cfgp, mesh=None)
ok = True
for name, v in ZOO:
    # interleaved V=2 over 4 devices needs 8 stage groups
    s = 8 if name == "interleaved" else 4
    sp = split_stages(jax.random.normal(jax.random.PRNGKey(1), (8, 4, 4)), 8, s)
    cfg_z = PipelineConfig(num_stages=s, num_microbatches=4, axis_name="pod",
                           schedule=name, interleave=v)
    yl = pipeline_apply(stage_fn, sp, x, cfg_z, mesh=None)
    yp = pipeline_apply(stage_fn, sp, x, cfg_z, mesh=mesh)
    ok &= bool(jnp.allclose(yl, yp, atol=1e-6))
y_pipe = pipeline_apply(stage_fn, stage_params, x, cfgp, mesh=mesh)
print("PIPE", bool(jnp.allclose(y_lazy, y_pipe, atol=1e-6)) and ok)

# 3b. pipeline_apply with backward="planned": the training wrapper's
# gradients match the autodiff path bitwise (1F1B stage split)
cfg_a = PipelineConfig(num_stages=4, num_microbatches=4, axis_name="pod",
                       schedule="one_f_one_b")
cfg_p = PipelineConfig(num_stages=4, num_microbatches=4, axis_name="pod",
                       schedule="one_f_one_b", backward="planned")
pa_loss = lambda sp, cfg: jnp.sum(
    pipeline_apply(stage_fn, sp, x, cfg, mesh=mesh) ** 2)
g_pa = jax.grad(lambda sp: pa_loss(sp, cfg_a))(stage_params)
g_pp = jax.grad(lambda sp: pa_loss(sp, cfg_p))(stage_params)
print("PLANNED_PIPELINE_APPLY", bool(jnp.all(g_pa == g_pp)))

# 4. the paper's sieve under the Future monad
ref = sieve.reference_primes(600)
p4, c4 = sieve.run_sieve(600, block_size=64, primes_per_cell=2, num_cells=56,
                         evaluator=fut)
p4 = np.asarray(p4)
print("SIEVE", int(c4) == len(ref) and np.array_equal(p4[p4 > 0], ref))

# 5. polynomial multiplication under the Future monad
x5 = poly.fateman_poly(3, 20, 6)
ref5 = poly.reference_product(poly.to_dict(x5), poly.to_dict(x5))
got5 = poly.to_dict(poly.times(x5, x5, evaluator=fut, num_x_chunks=4,
                               terms_per_cell=5, acc_capacity=256))
print("POLY", got5 == ref5)

# 5b. the combinator algebra: every combinator, Lazy == Future *bitwise*
# across the schedule zoo (map fusion, entry zip, interior zip, concat,
# mask, chained segments)
a7 = jnp.linspace(0, 1, 18).reshape(6, 3)
b7 = jnp.linspace(1, 2, 18).reshape(6, 3)
w8 = jnp.arange(8, dtype=jnp.float32)
w4a = jnp.arange(4, dtype=jnp.float32)
w4b = jnp.linspace(0.5, 1.5, 4)
cell2 = lambda w, x: (w, jnp.tanh(x * w))
PROGRAMS = {
    "map": Stream.source(a7).map(lambda x: x * 2.0).through(cell, w8)
        .map(lambda x: x + 1.0),
    "zip_entry": Stream.source(a7)
        .zip(Stream.source(b7), lambda x, y: x * y).through(cell, w8),
    "zip_mid": Stream.source(a7).through(cell, w4a)
        .zip(Stream.source(b7), lambda f, s: f + s)
        .through(cell2, w4b, mutable_state=False),
    "concat": Stream.source(a7[:3]).concat(Stream.source(a7[3:]))
        .through(cell, w8),
    "mask": Stream.source(a7).mask(lambda v: v > 0.3)
        .map(lambda d: d["value"] * d["valid"].astype(jnp.float32))
        .through(cell, w8),
    "two_seg": Stream.source(a7).through(cell, w4a)
        .through(cell2, w4b, mutable_state=False),
    # structure-preserving map between segments: fuses into the downstream
    # segment's pre_fn, the lax.cond(pos==0) path in unify_segments
    "mid_map": Stream.source(a7).through(cell, w4a)
        .map(lambda x: x * 0.5 + 0.1)
        .through(cell2, w4b, mutable_state=False),
}
ok = True
for pname, sprog in PROGRAMS.items():
    rl = sprog.collect(LazyEvaluator())
    for name, v in ZOO:
        ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
        rf = sprog.collect(ev)
        same = all(bool(jnp.all(x == y)) for x, y in
                   zip(jax.tree.leaves(rl.items), jax.tree.leaves(rf.items)))
        same &= all(bool(jnp.all(x == y)) for x, y in
                    zip(jax.tree.leaves(rl.states), jax.tree.leaves(rf.states)))
        if not same:
            print("# algebra mismatch:", pname, name)
        ok &= same
print("ALGEBRA_ZOO", ok)

# 5c. polynomial multiplication as a genuine two-source zip: bit-identical
# Lazy vs Future on every schedule, both sources injected through the
# generalized carousel — no replication collective in the lowered HLO
x7 = poly.fateman_poly(3, 24, 6)  # 8 cells at G=3: divisible for V=2
mkst = lambda: poly.times_stream(x7, x7, num_x_chunks=4, terms_per_cell=3,
                                 acc_capacity=256)
rl7 = mkst().collect(LazyEvaluator())
okp = True
for name, v in ZOO:
    ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
    rf7 = mkst().collect(ev)
    okp &= all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(rl7.items), jax.tree.leaves(rf7.items)))
print("POLY_ZIP_ZOO", okp)
assert len(mkst().lower().injections) == 2  # two real sources, one zip
hlo7 = jax.jit(lambda: mkst().collect(fut).items).lower().compile().as_text()
print("POLY_ZIP_NO_REPLICATION",
      ("all-reduce" not in hlo7) and ("all-gather" not in hlo7))

# 5c2. the feedback/unfold combinator: Lazy == Future bitwise across the
# schedule zoo (the serving decode loop's shape: emitted items re-enter
# with lag = in-flight microbatches)
fbcell = lambda s, x: (s + 1.0, jnp.tanh(x * 1.01) + s * 0.001)
fbemit = lambda x: x * 0.9 + 1.0
fbst = jnp.arange(8, dtype=jnp.float32)
okf = True
for lag, n in [(8, 24), (4, 16), (3, 14)]:
    fbinit = jnp.linspace(0., 1., lag * 3).reshape(lag, 3)
    mkfb = lambda _i=fbinit, _n=n: Stream.feedback(_i, _n, fbemit).through(fbcell, fbst)
    rfl = mkfb().collect(LazyEvaluator())
    for name, v in ZOO:
        ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
        rff = mkfb().collect(ev)
        okf &= all(bool(jnp.all(x == y)) for x, y in
                   zip(jax.tree.leaves(rfl.items), jax.tree.leaves(rff.items)))
        okf &= all(bool(jnp.all(x == y)) for x, y in
                   zip(jax.tree.leaves(rfl.states), jax.tree.leaves(rff.states)))
print("FEEDBACK_ZOO", okf)

# 5c3. the read-only/mutable state split: const_state rides scan xs only
# (stage-sharded, never carried, never written back) — bitwise Lazy ==
# Future across the zoo for plain AND feedback chains, mutable and not
ccell = lambda c, s, x: (s + 1.0, jnp.tanh(x * c) + s * 0.01)
cst = jnp.linspace(1.0, 2.0, 8)
cw = jnp.arange(8, dtype=jnp.float32)
okc = True
mkc = lambda: Stream.source(a7).through(ccell, cw, const_state=cst)
rcl = mkc().collect(LazyEvaluator())
fbc_init = jnp.linspace(0., 1., 12).reshape(4, 3)
mkcf = lambda: Stream.feedback(fbc_init, 16, fbemit).through(
    ccell, cw, const_state=cst)
rcl2 = mkcf().collect(LazyEvaluator())
for name, v in ZOO:
    ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
    rcf = mkc().collect(ev)
    okc &= all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(rcl.items), jax.tree.leaves(rcf.items)))
    okc &= all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(rcl.states), jax.tree.leaves(rcf.states)))
    rcf2 = mkcf().collect(ev)
    okc &= all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(rcl2.items), jax.tree.leaves(rcf2.items)))
    okc &= all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(rcl2.states), jax.tree.leaves(rcf2.states)))
print("CONST_ZOO", okc)

# 5d. fused multiply-add x*y + z rides the accumulator source
z7 = poly.from_dict({(1, 2, 3): 7, (0, 0, 1): 5}, 8, 6)
fma = poly.to_dict(poly.times_into(x7, x7, z7, evaluator=fut, num_x_chunks=4,
                                   terms_per_cell=3, acc_capacity=256))
want7 = dict(poly.reference_product(poly.to_dict(x7), poly.to_dict(x7)))
for k, vv in poly.to_dict(z7).items():
    want7[k] = want7.get(k, 0) + vv
print("POLY_FMA", fma == {k: v for k, v in want7.items() if v})

# 6. sharded train step on a 2x2 (data, model) mesh
from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.parallel import sharding as SH
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step
mesh2 = compat.make_mesh((2, 2), ("data", "model"),
                         axis_types=(compat.AxisType.Auto,) * 2)
sc = smoke_config(get_config("qwen3-32b"))
layout = T.model_layout(sc)
params = init_params(jax.random.PRNGKey(0), layout)
opt = init_opt_state(params, AdamWConfig())
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, sc.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
step = make_train_step(sc, TrainConfig(num_microbatches=2, attn_impl="dense"),
                       AdamWConfig())
ref_out = step(params, opt, batch)  # unsharded reference
with compat.set_mesh(mesh2):
    shardings = SH.param_shardings(layout, SH.TRAIN_RULES, mesh2)
    params_s = jax.device_put(params, shardings)
    opt_s = init_opt_state(params_s, AdamWConfig())
    pspecs = SH.param_pspecs(layout, SH.TRAIN_RULES, mesh2)
    step_s = make_train_step(sc, TrainConfig(num_microbatches=2, attn_impl="dense"),
                             AdamWConfig(), param_pspecs=pspecs)
    out_s = jax.jit(step_s)(params_s, opt_s, batch)
ok = True
for a, b in zip(jax.tree.leaves(ref_out[0]), jax.tree.leaves(out_s[0])):
    ok &= bool(jnp.allclose(a.astype(jnp.float32), np.asarray(b, np.float32), atol=2e-2))
print("SHARDED_TRAIN", ok, float(ref_out[2]["loss"]), float(out_s[2]["loss"]))
"""


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
        stdin=subprocess.DEVNULL,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return dict(
        line.split(None, 1) for line in proc.stdout.strip().splitlines()
    )


def test_lazy_future_equivalence(report):
    assert report["EQUIV"].startswith("True")


def test_lazy_future_equivalence_ragged(report):
    assert report["EQUIV_RAGGED"].startswith("True")


def test_gradient_equivalence(report):
    assert report["GRAD"].startswith("True")


def test_planned_backward_bitwise_gpipe_and_1f1b(report):
    # acceptance: planned-backward gradients bitwise-equal to jax.grad
    # of the forward plan on 4 simulated devices
    assert report["PLANNED_GRAD_BITWISE"].startswith("True")


def test_planned_backward_allclose_zoo(report):
    assert report["PLANNED_GRAD_CLOSE"].startswith("True")


def test_planned_forward_bit_identical(report):
    assert report["PLANNED_FWD"].startswith("True")


def test_planned_pipeline_apply_grads(report):
    assert report["PLANNED_PIPELINE_APPLY"].startswith("True")


def test_output_collection_has_no_psum(report):
    assert report["NO_PSUM_COLLECT"].startswith("True")


def test_pipeline_apply(report):
    assert report["PIPE"].startswith("True")


def test_sieve_future(report):
    assert report["SIEVE"].startswith("True")


def test_polynomial_future(report):
    assert report["POLY"].startswith("True")


def test_algebra_combinators_bitwise_across_schedules(report):
    assert report["ALGEBRA_ZOO"].startswith("True")


def test_polynomial_two_source_zip_across_schedules(report):
    assert report["POLY_ZIP_ZOO"].startswith("True")


def test_feedback_unfold_across_schedules(report):
    assert report["FEEDBACK_ZOO"].startswith("True")


def test_const_state_split_across_schedules(report):
    assert report["CONST_ZOO"].startswith("True")


def test_polynomial_zip_sources_not_replicated(report):
    assert report["POLY_ZIP_NO_REPLICATION"].startswith("True")


def test_polynomial_fused_multiply_add(report):
    assert report["POLY_FMA"].startswith("True")


def test_sharded_train_matches_unsharded(report):
    assert report["SHARDED_TRAIN"].startswith("True")
