"""Fused RMSNorm kernel vs oracle: shape/dtype sweep + model-layer parity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

CASES = [
    ((4, 128), jnp.float32, 1e-6),
    ((2, 16, 256), jnp.float32, 1e-6),
    ((3, 7, 512), jnp.bfloat16, 2e-2),   # ragged rows -> block walk-down
    ((1, 1024), jnp.bfloat16, 2e-2),
    ((256, 64), jnp.float32, 1e-6),
]


@pytest.mark.parametrize("case", CASES, ids=str)
def test_rmsnorm_kernel_vs_ref(case):
    shape, dt, tol = case
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape) * 3, dt)
    scale = jnp.asarray(rng.uniform(0.5, 1.5, size=shape[-1]), jnp.float32)
    out = rmsnorm(x, scale, interpret=True)
    ref = rmsnorm_ref(x, scale)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, err


def test_matches_model_layer():
    from repro.models.layers import rmsnorm as layer_rmsnorm

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 32, 128)), jnp.bfloat16)
    scale = jnp.asarray(rng.uniform(0.5, 1.5, size=128), jnp.float32)
    out = rmsnorm(x, scale, interpret=True)
    ref = layer_rmsnorm({"scale": scale}, x)
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))) < 2e-2
