"""Per-arch smoke tests (reduced configs) + decode/prefill equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, smoke_config, all_cells
from repro.models import transformer as T
from repro.models.params import init_params, param_count


def _inputs(sc, rng, B=2, S=16):
    kw = {}
    if sc.embeds_input:
        kw["embeds"] = jax.random.normal(rng, (B, S, sc.d_model), jnp.float32)
    else:
        kw["tokens"] = jax.random.randint(rng, (B, S), 0, sc.vocab_size)
    if sc.vision_tokens:
        kw["vision_embeds"] = jax.random.normal(
            rng, (B, sc.vision_tokens, sc.d_model), jnp.bfloat16
        )
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch, rng):
    """Reduced config of the same family: one forward, shapes + no NaNs."""
    sc = smoke_config(get_config(arch))
    params = init_params(rng, T.model_layout(sc))
    B, S = 2, 16
    logits, _, aux = T.forward(params, sc, attn_impl="dense", **_inputs(sc, rng, B, S))
    assert logits.shape == (B, S, sc.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if sc.moe is not None:
        assert float(aux["moe_lb_loss"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    """One real optimizer step on the reduced config; finite loss + updates."""
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_step import TrainConfig, make_train_step

    sc = smoke_config(get_config(arch))
    params = init_params(rng, T.model_layout(sc))
    opt = init_opt_state(params, AdamWConfig())
    B, S = 2, 16
    batch = dict(_inputs(sc, rng, B, S))
    batch["labels"] = jax.random.randint(rng, (B, S), 0, sc.vocab_size)
    if "tokens" not in batch and not sc.embeds_input:
        batch["tokens"] = jax.random.randint(rng, (B, S), 0, sc.vocab_size)
    step = make_train_step(
        sc, TrainConfig(num_microbatches=2, attn_impl="dense", remat=True),
        AdamWConfig(),
    )
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # at least one leaf changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert changed


@pytest.mark.parametrize(
    "arch",
    ["jamba-1.5-large-398b", "llama-3.2-vision-90b", "mamba2-1.3b",
     "musicgen-medium", "qwen1.5-4b", "moonshot-v1-16b-a3b"],
)
def test_decode_matches_forward(arch, rng):
    """Incremental decode with caches == full forward (all block types)."""
    sc = smoke_config(get_config(arch))
    params = init_params(rng, T.model_layout(sc))
    B, S, MAX = 2, 8, 32
    kw = _inputs(sc, rng, B, S)
    logits_full, caches_ref, _ = T.forward(
        params, sc, attn_impl="dense", collect_kv=True, cache_pad_to=MAX, **kw
    )
    cache = T.init_cache(sc, B, MAX)
    if sc.vision_tokens:
        for key in cache:
            if cache[key]["k"].shape[2] == sc.vision_tokens:
                cache[key] = caches_ref[key]
    outs = []
    for t in range(S):
        dkw = {}
        if sc.embeds_input:
            dkw["embeds"] = kw["embeds"][:, t : t + 1]
        else:
            dkw["tokens"] = kw["tokens"][:, t]
        lg, cache = T.decode_step(
            params, cache, sc,
            lengths=jnp.full((B,), t, jnp.int32), attn_impl="dense", **dkw
        )
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - logits_full)))
    assert err < 0.35, err  # bf16 params, fp32 logits


def test_chunked_prefill_matches_forward(rng):
    sc = smoke_config(get_config("qwen3-32b"))
    params = init_params(rng, T.model_layout(sc))
    B, S, CK = 2, 16, 4
    tokens = jax.random.randint(rng, (B, S), 0, sc.vocab_size)
    logits_full, _, _ = T.forward(params, sc, tokens=tokens, attn_impl="dense")
    cache = T.init_cache(sc, B, S)
    for c in range(S // CK):
        lg, cache = T.prefill_step(
            params, cache, sc,
            tokens=tokens[:, c * CK : (c + 1) * CK], pos=c * CK,
            attn_impl="dense",
        )
    err = float(jnp.max(jnp.abs(lg - logits_full[:, -1, :])))
    assert err < 0.35, err


def test_attention_impl_equivalence(rng):
    """dense vs chunked lowerings agree (flash oracle chain)."""
    sc = smoke_config(get_config("internlm2-20b"))
    params = init_params(rng, T.model_layout(sc))
    tokens = jax.random.randint(rng, (2, 32), 0, sc.vocab_size)
    ld, _, _ = T.forward(params, sc, tokens=tokens, attn_impl="dense")
    lc, _, _ = T.forward(
        params, sc, tokens=tokens, attn_impl="chunked", q_chunk=8, kv_chunk=16
    )
    assert float(jnp.max(jnp.abs(ld - lc))) < 0.25


def test_assigned_cells_cover_40_minus_skips():
    cells = all_cells()
    # 10 archs × 3 universal shapes + long_500k for the 2 sub-quadratic archs
    assert len(cells) == 32
    for arch, shape in cells:
        assert shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    longs = [a for a, s in cells if s == "long_500k"]
    assert sorted(longs) == ["jamba-1.5-large-398b", "mamba2-1.3b"]


def test_param_counts_match_names():
    expected = {
        "jamba-1.5-large-398b": (390e9, 410e9),
        "qwen1.5-4b": (3.5e9, 4.5e9),
        "olmo-1b": (1.0e9, 1.4e9),
        "internlm2-20b": (18e9, 22e9),
        "qwen3-32b": (30e9, 35e9),
        "llama4-maverick-400b-a17b": (390e9, 410e9),
        "llama-3.2-vision-90b": (80e9, 95e9),
        "mamba2-1.3b": (1.1e9, 1.5e9),
        "musicgen-medium": (1.3e9, 2.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(T.model_layout(get_config(arch)))
        assert lo <= n <= hi, (arch, n)
