"""Real hypothesis when installed; a skipping stub when not.

Tier-1 must collect and run offline.  A bare ``import hypothesis`` used
to abort collection of seven modules; ``pytest.importorskip`` would skip
those modules *wholesale*, losing every non-property test in them.  This
shim keeps the module importable either way: with hypothesis absent,
``@hypothesis.given(...)`` marks just that test skipped and strategy
constructors return inert placeholders.
"""
try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # offline container: skip only the property tests
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Placeholder accepted (and ignored) by the stub decorators."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):  # .filter/.map/.flatmap chains
            return self

    class _StrategiesModule:
        def __getattr__(self, name):
            return _Strategy()

    class _HypothesisModule:
        @staticmethod
        def given(*args, **kwargs):
            return pytest.mark.skip(reason="hypothesis not installed")

        @staticmethod
        def settings(*args, **kwargs):
            return lambda fn: fn

    hypothesis = _HypothesisModule()
    st = _StrategiesModule()

__all__ = ["HAVE_HYPOTHESIS", "hypothesis", "st"]
