"""Resilient serving: the chaos battery and request-lifecycle guards.

The acceptance bar (ISSUE 10): a fault injected at *every* round index —
mid-round exception, NaN-poisoned cache, SIGTERM — loses zero accepted
requests and the recovered serve's tokens are bitwise-equal to the
fault-free run, for the sequential ``Engine`` and the ``StreamEngine``
(xla and pallas-interpret here; gpipe/interleaved on 4 devices in the
multidevice battery below).  Bitwise replay is the paper's determinism
carried into the failure path: failure is a value, recovery re-runs the
same pure flow.

Runtime discipline: each battery builds ONE engine (one jit compile),
takes a pristine supervisor snapshot at birth, uses the fault-free run
as both golden and warmup, and replays every chaos scenario from the
pristine snapshot — restore resets the uid counter, so resubmitted
workloads are bitwise-identical without recompiling.
"""
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DecodePipelineConfig
from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.resilience import (
    Heartbeat,
    InjectedFault,
    OneShotInjector,
    RestartBudget,
    RestartPolicy,
    StragglerTracker,
)
from repro.resilience.injection import call_injector
from repro.serve.engine import (
    DrainTimeoutError,
    Engine,
    QueueFullError,
    ServeConfig,
    StreamEngine,
)
from repro.serve.supervisor import (
    DrainingError,
    NumericsFault,
    ServeSupervisor,
    SupervisorConfig,
    WatchdogTimeout,
    chaos_injector,
    poison_cache,
)

PROMPTS = [
    np.array([5, 9, 2, 7]),
    np.array([3, 1]),
    np.array([2] * 5),
    np.array([8, 8, 4]),
]
BUDGETS = [4, 2, 3, 4]

SCFG = dict(max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=4)


@pytest.fixture(scope="module")
def cell_model():
    rng = jax.random.PRNGKey(0)
    sc = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=4)
    params = init_params(rng, T.model_layout(sc))
    return sc, params


def _submit_all(sup):
    return [sup.submit(p, b) for p, b in zip(PROMPTS, BUDGETS)]


def _rig(engine):
    """(pristine snapshot, golden tokens, clean round count) for ``engine``.

    The fault-free supervised run doubles as jit warmup; the pristine
    snapshot (taken before any submit) is the reset lever every chaos
    scenario replays from.
    """
    sup = ServeSupervisor(engine)
    pristine = sup.snapshot()
    reqs = _submit_all(sup)
    sup.run_until_drained()
    golden = [r.out_tokens for r in reqs]
    assert all(r.done for r in reqs)
    return pristine, golden, sup.stats["rounds"]


@pytest.fixture(scope="module")
def seq_rig(cell_model):
    sc, params = cell_model
    eng = Engine(params, sc, ServeConfig(**SCFG))
    pristine, golden, rounds = _rig(eng)
    return eng, pristine, golden, rounds


@pytest.fixture(scope="module")
def stream_rig(cell_model):
    sc, params = cell_model
    eng = StreamEngine(
        params, sc, ServeConfig(**SCFG),
        DecodePipelineConfig(num_cells=2, microbatches=2, round_steps=3,
                             admit_per_round=2),
    )
    pristine, golden, rounds = _rig(eng)
    return eng, pristine, golden, rounds


@pytest.fixture(scope="module")
def pallas_rig(cell_model):
    sc, params = cell_model
    eng = StreamEngine(
        params, sc, ServeConfig(**SCFG),
        DecodePipelineConfig(num_cells=2, microbatches=2, round_steps=3,
                             admit_per_round=2, kernels="pallas"),
    )
    assert not eng.degraded
    pristine, golden, rounds = _rig(eng)
    return eng, pristine, golden, rounds


def _chaos_run(rig, kind, k, cfg=None, **inj_kw):
    """Replay the golden workload with a ``kind`` fault at round ``k``."""
    eng, pristine, golden, _ = rig
    sup = ServeSupervisor(
        eng, cfg or SupervisorConfig(),
        fail_injector=chaos_injector(kind, k, **inj_kw),
    )
    sup.restore(pristine)
    reqs = _submit_all(sup)
    if kind == "sigterm":
        prev = signal.getsignal(signal.SIGTERM)
        sup.install_signal_handlers()
        try:
            sup.run_until_drained()
        finally:
            signal.signal(signal.SIGTERM, prev)
        assert sup.draining
    else:
        sup.run_until_drained()
    assert sup.stats["requests_lost"] == 0, (kind, k, sup.stats)
    assert [r.out_tokens for r in reqs] == golden, (kind, k)
    return sup


class TestChaosEngine:
    """Sequential Engine under the supervisor: every fault class at
    every round index recovers bitwise with zero requests lost."""

    def test_raise_every_round(self, seq_rig):
        rounds = seq_rig[3]
        for k in range(rounds):
            sup = _chaos_run(seq_rig, "raise", k)
            assert sup.stats["faults"] == 1 and sup.stats["restarts"] == 1

    def test_nan_poison_every_round(self, seq_rig):
        rounds = seq_rig[3]
        detected = 0
        for k in range(rounds):
            sup = _chaos_run(seq_rig, "nan", k)
            # A round that re-prefills every slot can fully overwrite the
            # poison — then there is nothing to detect and the run is
            # clean.  Whenever poison survives the round it must be
            # caught, restored, and replayed (never silently served).
            assert sup.stats["faults"] == sup.stats["restarts"] <= 1
            if sup.stats["faults"]:
                detected += 1
                assert any(
                    "NumericsFault" in e.get("error", "") for e in sup.events
                ), k
        assert detected >= rounds - 1

    def test_sigterm_every_round_drains_gracefully(self, seq_rig):
        rounds = seq_rig[3]
        for k in range(rounds):
            sup = _chaos_run(seq_rig, "sigterm", k)
            # SIGTERM is not a fault: admission closes, accepted work runs
            # to completion, and the drain event is recorded.
            assert sup.stats["faults"] == 0
            assert {"event": "drained"} in sup.events

    def test_wedge_trips_watchdog_and_replays(self, seq_rig):
        sup = _chaos_run(
            seq_rig, "wedge", 1,
            cfg=SupervisorConfig(deadline_s=0.3), wedge_seconds=0.6,
        )
        assert sup.stats["restarts"] == 1
        assert any(
            "WatchdogTimeout" in e.get("error", "") for e in sup.events
        )


class TestChaosStream:
    """StreamEngine (LazyEvaluator round program) under the supervisor:
    cell_states (the sharded KV slabs) snapshot/restore bitwise."""

    def test_stream_matches_sequential_golden(self, seq_rig, stream_rig):
        # cross-engine pin: the stream rig's fault-free tokens are the
        # sequential engine's, so chaos equality below is transitive.
        assert stream_rig[2] == seq_rig[2]

    def test_raise_every_round(self, stream_rig):
        for k in range(stream_rig[3]):
            sup = _chaos_run(stream_rig, "raise", k)
            assert sup.stats["restarts"] == 1

    def test_nan_poison_every_round(self, stream_rig):
        for k in range(stream_rig[3]):
            _chaos_run(stream_rig, "nan", k)

    def test_sigterm_every_round(self, stream_rig):
        for k in range(stream_rig[3]):
            sup = _chaos_run(stream_rig, "sigterm", k)
            assert sup.stats["faults"] == 0


class TestChaosPallas:
    """kernels="pallas" (interpret-emulated on CPU): the fused round
    program recovers bitwise too — fault tolerance is kernel-agnostic."""

    def test_pallas_matches_sequential_golden(self, seq_rig, pallas_rig):
        assert pallas_rig[2] == seq_rig[2]

    def test_raise_every_round(self, pallas_rig):
        for k in range(pallas_rig[3]):
            _chaos_run(pallas_rig, "raise", k)

    def test_nan_poison_recovers(self, pallas_rig):
        _chaos_run(pallas_rig, "nan", 1)


class TestSupervisorEdge:
    def test_budget_exhaustion_counts_lost_and_reraises(self, cell_model):
        sc, params = cell_model
        eng = Engine(params, sc, ServeConfig(**SCFG))
        def always_fail(step, engine):
            raise InjectedFault("persistent failure")
        sup = ServeSupervisor(
            eng, SupervisorConfig(max_restarts=2), fail_injector=always_fail
        )
        reqs = _submit_all(sup)
        with pytest.raises(InjectedFault):
            sup.run_until_drained()
        assert sup.stats["requests_lost"] == len(reqs)
        assert sup.stats["restarts"] == 2 and sup.stats["faults"] == 3
        gave_up = [e for e in sup.events if e["event"] == "gave_up"]
        assert gave_up and gave_up[0]["requests_lost"] == sorted(
            r.uid for r in reqs
        )

    def test_pristine_restore_is_bitwise_repeatable(self, seq_rig):
        eng, pristine, golden, _ = seq_rig
        for _ in range(2):
            sup = ServeSupervisor(eng)
            sup.restore(pristine)
            reqs = _submit_all(sup)
            sup.run_until_drained()
            assert [r.out_tokens for r in reqs] == golden

    def test_submit_after_drain_requested_rejected(self, seq_rig):
        eng, pristine, _, _ = seq_rig
        sup = ServeSupervisor(eng)
        sup.restore(pristine)
        sup.request_drain()
        with pytest.raises(DrainingError):
            sup.submit(np.array([1, 2]))

    def test_numerics_check_detects_poison(self, seq_rig):
        eng, pristine, _, _ = seq_rig
        sup = ServeSupervisor(eng)
        sup.restore(pristine)
        poison_cache(eng)
        with pytest.raises(NumericsFault):
            sup._check_numerics()
        sup.restore(pristine)
        sup._check_numerics()  # clean after restore

    def test_run_until_drained_counts_truncation_as_lost(self, seq_rig):
        eng, pristine, _, _ = seq_rig
        sup = ServeSupervisor(eng)
        sup.restore(pristine)
        _submit_all(sup)
        with pytest.raises(DrainTimeoutError) as ei:
            sup.run_until_drained(max_steps=1)
        assert sup.stats["requests_lost"] == len(ei.value.undrained) > 0
        sup2 = ServeSupervisor(eng)
        sup2.restore(pristine)  # leave the shared rig engine clean


class TestRequestLifecycle:
    """Engine-level robustness: bounded queue, deadlines, cancellation,
    loud drain truncation."""

    def test_bounded_queue_sheds_load(self, cell_model):
        sc, params = cell_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=1, max_len=64, prefill_chunk=4, max_queue=2))
        eng.submit(np.array([1, 2]))
        eng.submit(np.array([3, 4]))
        with pytest.raises(QueueFullError):
            eng.submit(np.array([5, 6]))
        assert {"event": "load_shed", "queue": 2} in eng.events
        assert len(eng.queue) == 2  # the shed request was never accepted

    def test_deadline_expires_queued_request(self, cell_model, seq_rig):
        sc, params = cell_model
        golden = seq_rig[2]
        eng = Engine(params, sc, ServeConfig(**SCFG))
        keep = [eng.submit(p, b) for p, b in zip(PROMPTS, BUDGETS)]
        dead = eng.submit(np.array([7, 7, 7]), 4, deadline_s=0.0)
        done = eng.run_until_drained()
        assert dead.done and dead.status == "expired" and dead in done
        assert dead.out_tokens == []
        # survivors are untouched by the expiry
        assert [r.out_tokens for r in keep] == golden
        assert all(r.status == "ok" for r in keep)

    def test_deadline_expires_active_request(self, cell_model):
        sc, params = cell_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=50))
        req = eng.submit(np.array([5, 9, 2]), deadline_s=0.15)
        eng.step()
        assert not req.done and any(r is req for r in eng.active)
        time.sleep(0.2)
        done = eng.step()
        assert req in done and req.status == "expired"
        assert len(req.out_tokens) > 0  # partial output is kept
        assert all(r is not req for r in eng.active)

    def test_cancel_queued_and_active(self, cell_model):
        sc, params = cell_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=1, max_len=64, prefill_chunk=4, max_new_tokens=6))
        ra = eng.submit(np.array([5, 9, 2]))
        rq = eng.submit(np.array([3, 1]))
        eng.step(); eng.step()
        assert eng.cancel(rq.uid)      # still queued
        assert eng.cancel(ra.uid)      # active in a slot
        assert not eng.cancel(9999)    # unknown uid
        assert ra.status == rq.status == "cancelled"
        assert ra.done and rq.done
        # the freed slot is reusable
        rest = eng.submit(np.array([2, 2]))
        eng.run_until_drained()
        assert rest.done and rest.status == "ok"

    def test_drain_truncation_raises_with_uids(self, cell_model):
        sc, params = cell_model
        eng = Engine(params, sc, ServeConfig(
            max_batch=2, max_len=64, prefill_chunk=4, max_new_tokens=50))
        req = eng.submit(np.array([5, 9, 2]))
        with pytest.raises(DrainTimeoutError) as ei:
            eng.run_until_drained(max_steps=2)
        assert ei.value.undrained == [req.uid]

    def test_stream_drain_truncation_raises(self, stream_rig):
        eng, pristine, _, _ = stream_rig
        sup = ServeSupervisor(eng)
        sup.restore(pristine)
        eng.submit(PROMPTS[0], 50)
        with pytest.raises(DrainTimeoutError):
            eng.run_until_drained(max_steps=1)
        sup.restore(pristine)  # leave the shared rig engine clean


class TestDegradedMode:
    """pallas → xla fallback: dispatch failure degrades (loudly) instead
    of killing the serve, and the xla replay is bitwise."""

    def test_init_probe_failure_degrades(self, cell_model, seq_rig, monkeypatch):
        sc, params = cell_model
        golden = seq_rig[2]
        import repro.kernels as K
        import repro.models.transformer as TT
        real = K.get_impl
        def broken(op, mode="auto"):
            if mode == "pallas":
                raise RuntimeError("simulated pallas import failure")
            return real(op, mode)
        monkeypatch.setattr(K, "get_impl", broken)
        monkeypatch.setattr(TT, "get_impl", broken)
        with pytest.warns(RuntimeWarning, match="degraded"):
            eng = StreamEngine(
                params, sc, ServeConfig(**SCFG),
                DecodePipelineConfig(num_cells=2, microbatches=2,
                                     round_steps=3, admit_per_round=2,
                                     kernels="pallas"),
            )
        assert eng.degraded and eng.kernels == "xla"
        assert eng.events[0]["event"] == "degraded"
        reqs = [eng.submit(p, b) for p, b in zip(PROMPTS, BUDGETS)]
        eng.run_until_drained()
        assert [r.out_tokens for r in reqs] == golden

    def test_midflight_round_failure_degrades_and_replays(
        self, cell_model, seq_rig
    ):
        sc, params = cell_model
        golden = seq_rig[2]
        eng = StreamEngine(
            params, sc, ServeConfig(**SCFG),
            DecodePipelineConfig(num_cells=2, microbatches=2, round_steps=3,
                                 admit_per_round=2, kernels="pallas"),
        )
        assert not eng.degraded

        def exploding_round(*a, **k):
            raise RuntimeError("simulated pallas lowering crash")

        eng._round = exploding_round
        reqs = [eng.submit(p, b) for p, b in zip(PROMPTS, BUDGETS)]
        with pytest.warns(RuntimeWarning, match="degraded"):
            eng.run_until_drained()
        # _build_programs() re-jitted a real xla round; tokens bitwise.
        assert eng.degraded and eng.kernels == "xla"
        assert [r.out_tokens for r in reqs] == golden


class TestResiliencePrimitives:
    def test_one_shot_injector_fires_once(self):
        hits = []
        inj = OneShotInjector(2, hits.append)
        for step in range(5):
            inj(step, f"t{step}")
        inj(2, "again")
        assert hits == ["t2"]

    def test_call_injector_arity(self):
        seen = []
        call_injector(lambda s: seen.append(("one", s)), 3, "eng")
        call_injector(lambda s, t: seen.append(("two", s, t)), 4, "eng")
        call_injector(None, 5)
        assert seen == [("one", 3), ("two", 4, "eng")]

    def test_restart_budget_and_backoff(self):
        b = RestartBudget(RestartPolicy(
            max_restarts=2, backoff_seconds=0.01, backoff_factor=2.0))
        assert b.admit() and b.next_delay() == pytest.approx(0.01)
        assert b.admit() and b.next_delay() == pytest.approx(0.02)
        assert b.exhausted and not b.admit()
        assert RestartBudget(RestartPolicy()).next_delay() == 0.0

    def test_heartbeat_roundtrip_and_staleness(self, tmp_path):
        path = str(tmp_path / "hb")
        assert Heartbeat.is_stale(path, 1.0)  # no file yet
        hb = Heartbeat(path)
        hb.beat(7)
        step, t = Heartbeat.read(path)
        assert step == 7
        assert not Heartbeat.is_stale(path, 60.0)
        assert Heartbeat.is_stale(path, 5.0, now=t + 10.0)
        Heartbeat(None).beat(0)  # disabled: no-op

    def test_straggler_tracker_flags_deviation(self):
        flagged = []
        t = StragglerTracker(factor=2.0, ema=0.9,
                             on_straggler=lambda s, r: flagged.append((s, r)))
        assert not t.observe(0, 1.0)   # seeds
        assert not t.observe(1, 1.1)
        assert t.observe(2, 5.0)
        assert flagged and flagged[0][0] == 2 and flagged[0][1] > 2.0
        assert t.count == 1

    def test_chaos_injector_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="chaos kind"):
            chaos_injector("meteor", 0)


# -- pipelined chaos battery (FutureEvaluator, 4 devices) --------------------

PIPELINE_SCRIPT = r"""
import os, signal
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro import compat
from repro.configs.base import DecodePipelineConfig
from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import Engine, ServeConfig, StreamEngine
from repro.serve.supervisor import ServeSupervisor, chaos_injector

sc = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=8)
params = init_params(jax.random.PRNGKey(0), T.model_layout(sc))
mesh = compat.make_mesh((4,), ("pod",), axis_types=(compat.AxisType.Auto,))

scfg = ServeConfig(max_batch=8, max_len=64, prefill_chunk=4, max_new_tokens=6)
rng = np.random.default_rng(7)
prompts = [rng.integers(1, sc.vocab_size, size=int(rng.integers(1, 9)))
           for _ in range(10)]
budgets = [int(b) for b in rng.integers(1, 7, size=10)]

ref = Engine(params, sc, scfg)
gr = [ref.submit(p, b) for p, b in zip(prompts, budgets)]
ref.run_until_drained()
golden = [r.out_tokens for r in gr]

for sched, v, cells, m in [("gpipe", 1, 8, 8), ("interleaved", 2, 8, 4)]:
    eng = StreamEngine(params, sc, scfg, DecodePipelineConfig(
        num_cells=cells, microbatches=m, schedule=sched, interleave=v,
        round_steps=4, admit_per_round=4), mesh=mesh)
    sup0 = ServeSupervisor(eng)
    pristine = sup0.snapshot()
    rc = [sup0.submit(p, b) for p, b in zip(prompts, budgets)]
    sup0.run_until_drained()
    rounds = sup0.stats["rounds"]
    ok = [r.out_tokens for r in rc] == golden
    scenarios = ([("raise", k) for k in range(rounds)]
                 + [("nan", min(1, rounds - 1)), ("sigterm", 0)])
    for kind, k in scenarios:
        sup = ServeSupervisor(eng, fail_injector=chaos_injector(kind, k))
        sup.restore(pristine)
        rs = [sup.submit(p, b) for p, b in zip(prompts, budgets)]
        if kind == "sigterm":
            prev = signal.getsignal(signal.SIGTERM)
            sup.install_signal_handlers()
            try:
                sup.run_until_drained()
            finally:
                signal.signal(signal.SIGTERM, prev)
        else:
            sup.run_until_drained()
        ok = (ok and sup.stats["requests_lost"] == 0
              and [r.out_tokens for r in rs] == golden)
        if not ok:
            print(f"# first failure: {sched} {kind}@{k} {sup.stats}")
            break
    print(f"CHAOS_{sched.upper()}", ok)
"""


@pytest.mark.multidevice
class TestChaosPipelined:
    """FutureEvaluator on 4 devices: every fault class recovers bitwise
    under gpipe and interleaved schedules (subprocess — forced host
    device count must be set before jax initialises)."""

    @pytest.fixture(scope="class")
    def report(self):
        import os
        import subprocess
        import sys
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src")
        proc = subprocess.run(
            [sys.executable, "-c", PIPELINE_SCRIPT],
            capture_output=True, text=True, env=env, timeout=1500,
            stdin=subprocess.DEVNULL,
        )
        assert proc.returncode == 0, proc.stderr[-3000:]
        return dict(
            line.split(None, 1)
            for line in proc.stdout.strip().splitlines()
            if not line.startswith("#")
        )

    def test_gpipe_chaos_zero_loss_bitwise(self, report):
        assert report["CHAOS_GPIPE"].startswith("True")

    def test_interleaved_chaos_zero_loss_bitwise(self, report):
        assert report["CHAOS_INTERLEAVED"].startswith("True")
