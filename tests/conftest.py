"""Shared fixtures.  NB: no XLA_FLAGS here — tests see the real device
count (1 on this container); multi-device behaviour is exercised via
subprocesses in test_multidevice.py, and the 512-device dry-run only ever
sets the flag inside repro.launch.dryrun."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
