"""Shared fixtures.  NB: no XLA_FLAGS here — tests see the real device
count (1 on this container); multi-device behaviour is exercised via
subprocesses in test_multidevice.py, and the 512-device dry-run only ever
sets the flag inside repro.launch.dryrun.

Subprocess-spawning multi-device tests carry the ``multidevice`` marker;
they are skipped cleanly when ``XLA_FLAGS=--xla_force_host_platform_
device_count`` cannot produce virtual devices (e.g. a non-CPU backend or
a stripped jaxlib), keeping tier-1 deterministic offline.
"""
import functools
import os
import subprocess
import sys

import jax
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: spawns a subprocess with XLA_FLAGS device-forcing "
        "(skipped when virtual devices are unavailable)",
    )


@functools.lru_cache(maxsize=1)
def _device_forcing_available() -> bool:
    # Inherit the environment untouched (notably JAX_PLATFORMS: without
    # it jax probes every plugin, which can hang on accelerator-less
    # containers); only the device-forcing flag is added.
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    try:
        proc = subprocess.run(
            [sys.executable, "-c", "import jax; assert jax.device_count() == 2"],
            capture_output=True,
            stdin=subprocess.DEVNULL,  # an inherited pipe stdin can hang jax init
            timeout=240,  # generous: under heavy load jax init can crawl
            env=env,
        )
    except (OSError, subprocess.TimeoutExpired):
        return False
    return proc.returncode == 0


def pytest_collection_modifyitems(config, items):
    for item in items:
        if "multidevice" in item.keywords and not _device_forcing_available():
            item.add_marker(
                pytest.mark.skip(
                    reason="XLA_FLAGS host-platform device-forcing unavailable"
                )
            )


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
