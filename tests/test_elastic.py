"""Elastic scaling: mesh re-derivation and state re-sharding."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.train.elastic import ElasticPlan, choose_mesh_shape, remesh_state


class TestChooseMeshShape:
    @hypothesis.given(st.sampled_from([8, 16, 32, 64, 128, 256, 384, 512]))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_uses_all_devices(self, n):
        plan = choose_mesh_shape(n)
        assert plan.mesh_shape[0] * plan.mesh_shape[1] == n

    @hypothesis.given(st.sampled_from([8, 16, 32, 64, 256, 512]))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_microbatches_divide_global_batch(self, n):
        plan = choose_mesh_shape(n, global_batch=256)
        assert 256 % plan.num_microbatches == 0

    def test_model_axis_shrinks_when_indivisible(self):
        plan = choose_mesh_shape(24, preferred_model=16)
        assert plan.mesh_shape == (3, 8)

    def test_halving_devices_keeps_running(self):
        # pod loss: 512 -> 256 (cordon one pod)
        before = choose_mesh_shape(512)
        after = choose_mesh_shape(256)
        assert after.mesh_shape[1] == before.mesh_shape[1] == 16
        assert after.mesh_shape[0] == before.mesh_shape[0] // 2


def test_remesh_state_roundtrip():
    """Restore-then-reshard onto a new (1-device) mesh preserves values."""
    from repro.configs.registry import get_config, smoke_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.parallel import sharding as SH

    sc = smoke_config(get_config("olmo-1b"))
    layout = T.model_layout(sc)
    params = init_params(jax.random.PRNGKey(0), layout)
    mesh = compat.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(compat.AxisType.Auto,) * 2,
    )
    resharded = remesh_state(params, layout, SH.TRAIN_RULES, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
