"""Elastic scaling: mesh re-derivation and state re-sharding."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.train.elastic import (
    ElasticPlan,
    choose_elastic_plan,
    choose_mesh_shape,
    remesh_state,
)


class TestChooseMeshShape:
    @hypothesis.given(st.sampled_from([8, 16, 32, 64, 128, 256, 384, 512]))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_uses_all_devices(self, n):
        plan = choose_mesh_shape(n)
        assert plan.mesh_shape[0] * plan.mesh_shape[1] == n

    @hypothesis.given(st.sampled_from([8, 16, 32, 64, 256, 512]))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_microbatches_divide_global_batch(self, n):
        plan = choose_mesh_shape(n, global_batch=256)
        assert 256 % plan.num_microbatches == 0

    def test_model_axis_shrinks_when_indivisible(self):
        plan = choose_mesh_shape(24, preferred_model=16)
        assert plan.mesh_shape == (3, 8)

    def test_halving_devices_keeps_running(self):
        # pod loss: 512 -> 256 (cordon one pod)
        before = choose_mesh_shape(512)
        after = choose_mesh_shape(256)
        assert after.mesh_shape[1] == before.mesh_shape[1] == 16
        assert after.mesh_shape[0] == before.mesh_shape[0] // 2


class TestScheduleAwareReplanning:
    """Satellite: node loss must re-run optimal_schedule, not just re-mesh
    — schedule, M and V are all pipeline-axis-dependent."""

    # Bubble-vs-overhead regime where the optimum genuinely moves with
    # pipeline depth: deep pipelines interleave, shallow ones fill/drain.
    KW = dict(
        preferred_pipeline=8,
        global_batch=256,
        work_per_item=1.0,
        per_tick_overhead=1e-5,
    )

    def test_schedule_changes_when_pipeline_axis_shrinks(self):
        before = choose_elastic_plan(16, **self.KW)  # pipe = 8
        after = choose_elastic_plan(2, **self.KW)  # pipe = 2
        assert before.mesh_shape[-1] == 8
        assert after.mesh_shape[-1] == 2
        assert before.schedule is not None and after.schedule is not None
        assert before.schedule.schedule == "interleaved"
        assert after.schedule.schedule == "gpipe"
        assert before.schedule != after.schedule

    def test_microbatches_divide_global_batch(self):
        for n in (2, 4, 8, 16, 32):
            plan = choose_elastic_plan(n, **self.KW)
            assert 256 % plan.num_microbatches == 0

    def test_unpipelined_has_no_schedule(self):
        plan = choose_elastic_plan(8, preferred_pipeline=1)
        assert plan.schedule is None
        assert plan.mesh_shape[-1] == 1
        assert plan.axis_names == ("data", "model", "pipe")

    def test_non_power_of_two_preference_keeps_pipelining(self):
        # preferred_pipeline=6 on 8 devices must land on pipe=4 (the
        # largest power-of-two divisor <= 6), not collapse to pipe=1
        plan = choose_elastic_plan(8, **{**self.KW, "preferred_pipeline": 6})
        assert plan.mesh_shape[-1] == 4
        assert plan.schedule is not None

    def test_replan_respects_memory_budget(self):
        # a planned-backward job: the budget may exploit the combined
        # plans' real stash bounds (min(S, M) for 1F1B, V*min(S, M)
        # interleaved)
        plan = choose_elastic_plan(
            16, **{**self.KW, "memory_budget_items": 0.5,
                   "backward": "planned"}
        )
        choice = plan.schedule
        assert choice is not None
        # the choice IS the plan: M constrained to divide the global
        # batch inside the search, so the budget was checked at the M
        # that actually runs
        assert plan.num_microbatches == choice.num_chunks
        assert 256 % plan.num_microbatches == 0
        from repro.core.chunking import schedule_peak_items

        peak = schedule_peak_items(
            choice.schedule, 8, plan.num_microbatches, choice.interleave,
            backward="planned",
        )
        assert peak / plan.num_microbatches <= 0.5
        # gpipe's peak/M is always 1.0: the budget must have excluded it
        assert choice.schedule != "gpipe"

    def test_autodiff_job_budget_is_honest(self):
        # the default (autodiff-backward) job cannot buy memory with
        # 1F1B: every schedule keeps all V*M unit inputs live, so a
        # sub-1.0 budget must be reported infeasible, not silently
        # scored against a stash bound the execution never realizes
        with pytest.raises(ValueError, match="fits memory_budget"):
            choose_elastic_plan(
                16, **{**self.KW, "memory_budget_items": 0.5}
            )

    @hypothesis.given(st.sampled_from([2, 4, 8, 16, 24, 48]))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_pipeline_axis_divides_devices(self, n):
        plan = choose_elastic_plan(n, **self.KW)
        pipe = plan.mesh_shape[-1]
        assert n % pipe == 0
        assert int(np.prod(plan.mesh_shape)) == n


def test_remesh_state_roundtrip():
    """Restore-then-reshard onto a new (1-device) mesh preserves values."""
    from repro.configs.registry import get_config, smoke_config
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.parallel import sharding as SH

    sc = smoke_config(get_config("olmo-1b"))
    layout = T.model_layout(sc)
    params = init_params(jax.random.PRNGKey(0), layout)
    mesh = compat.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(compat.AxisType.Auto,) * 2,
    )
    resharded = remesh_state(params, layout, SH.TRAIN_RULES, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
