"""MoE dispatch invariants + equivalence to a dense one-hot reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import moe as M
from repro.models.params import init_params


def _setup(rng, e=4, k=2, d=16, f=32, b=2, s=8, shared=0, cf=8.0):
    cfg = ArchConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=f, vocab_size=32, head_dim=8,
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=f,
                      num_shared_experts=shared, capacity_factor=cf),
    )
    params = init_params(rng, M.moe_layout(cfg, cfg.moe))
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, d), jnp.float32)
    return cfg, params, x


def _dense_reference(params, x, moe_cfg):
    """Every expert processes every token; outputs weighted by router."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, moe_cfg.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # per-expert full FFN
    gate = jnp.einsum("td,edf->etf", xf, params["w_gate"])
    up = jnp.einsum("td,edf->etf", xf, params["w_up"])
    act = jax.nn.silu(gate) * up
    out = jnp.einsum("etf,efd->etd", act, params["w_down"])  # (E, T, d)
    y = jnp.zeros_like(xf)
    for kk in range(moe_cfg.top_k):
        sel = expert_ids[:, kk]  # (T,)
        y = y + gate_vals[:, kk:kk+1] * out[sel, jnp.arange(xf.shape[0])]
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference_when_no_drops(rng):
    cfg, params, x = _setup(rng, cf=16.0)  # capacity >> tokens: no drops
    y, aux = M.moe_apply(params, x, cfg.moe)
    y_ref = _dense_reference(params, x, cfg.moe)
    assert float(aux["moe_drop_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_moe_capacity_drops_tokens(rng):
    cfg, params, x = _setup(rng, e=2, k=1, b=2, s=16, cf=0.25)
    y, aux = M.moe_apply(params, x, cfg.moe)
    assert float(aux["moe_drop_fraction"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_shared_experts_added(rng):
    cfg, params, x = _setup(rng, shared=1, cf=16.0)
    y, _ = M.moe_apply(params, x, cfg.moe)
    sh = params["shared"]
    g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, sh["w_up"])
    shared_out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, sh["w_down"])
    y_routed = y - shared_out
    y_ref = _dense_reference(params, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(y_routed), np.asarray(y_ref), atol=1e-3)


def test_moe_lb_loss_uniform_is_one(rng):
    """With a uniform router, the Switch LB loss is ~1 (its minimum)."""
    cfg, params, x = _setup(rng, e=8, k=1, b=4, s=64, cf=16.0)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    _, aux = M.moe_apply(params, x, cfg.moe)
    # ties in top_k pick expert 0, so fraction is degenerate, but prob_mean
    # is uniform: loss = E * sum(frac * 1/E) = 1 exactly.
    assert abs(float(aux["moe_lb_loss"]) - 1.0) < 1e-5


def test_moe_grad_flows(rng):
    cfg, params, x = _setup(rng, cf=16.0)

    def loss(params):
        y, aux = M.moe_apply(params, x, cfg.moe)
        return jnp.sum(y**2) + aux["moe_lb_loss"]

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
