"""Optimizer, checkpointing, fault tolerance, data pipeline, compression."""
import os
import time

import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, PrefetchIterator, make_source, host_shard
from repro.train import compression
from repro.train.checkpoint import Checkpointer
from repro.train.fault import FaultConfig, ResilientLoop
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
)


class TestOptimizer:
    def _numpy_adamw(self, p, g, m, v, step, cfg):
        gnorm = np.sqrt(sum(np.sum(np.square(x)) for x in g.values()))
        scale = min(1.0, cfg.clip_norm / max(gnorm, 1e-9))
        lr = float(lr_schedule(jnp.asarray(step), cfg))
        out_p, out_m, out_v = {}, {}, {}
        for k in p:
            gg = g[k] * scale
            out_m[k] = cfg.beta1 * m[k] + (1 - cfg.beta1) * gg
            out_v[k] = cfg.beta2 * v[k] + (1 - cfg.beta2) * gg * gg
            mh = out_m[k] / (1 - cfg.beta1**step)
            vh = out_v[k] / (1 - cfg.beta2**step)
            upd = mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p[k]
            out_p[k] = p[k] - lr * upd
        return out_p, out_m, out_v

    def test_matches_numpy_reference(self):
        rng = np.random.default_rng(0)
        cfg = AdamWConfig(learning_rate=1e-2, warmup_steps=0)
        p = {"a": rng.normal(size=(4, 3)).astype(np.float32),
             "b": rng.normal(size=(5,)).astype(np.float32)}
        g = {k: rng.normal(size=v.shape).astype(np.float32) for k, v in p.items()}
        params = jax.tree.map(jnp.asarray, p)
        opt = init_opt_state(params, cfg)
        new_p, new_opt, metrics = adamw_update(params, jax.tree.map(jnp.asarray, g), opt, cfg)
        ref_p, ref_m, ref_v = self._numpy_adamw(
            p, g, {k: np.zeros_like(v) for k, v in p.items()},
            {k: np.zeros_like(v) for k, v in p.items()}, 1, cfg,
        )
        for k in p:
            np.testing.assert_allclose(np.asarray(new_p[k]), ref_p[k], rtol=2e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(new_opt["m"][k]), ref_m[k], rtol=2e-5, atol=1e-6)

    def test_clip_caps_update(self):
        cfg = AdamWConfig(clip_norm=1e-3, weight_decay=0.0, warmup_steps=0)
        params = {"w": jnp.ones((8,))}
        grads = {"w": jnp.full((8,), 100.0)}
        opt = init_opt_state(params, cfg)
        _, _, metrics = adamw_update(params, grads, opt, cfg)
        assert float(metrics["grad_norm"]) > 100

    def test_bf16_moments_roundtrip(self):
        cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
        params = {"w": jnp.ones((4,))}
        opt = init_opt_state(params, cfg)
        assert opt["m"]["w"].dtype == jnp.bfloat16
        new_p, new_opt, _ = adamw_update(params, {"w": jnp.ones((4,)) * 0.1}, opt, cfg)
        assert new_opt["v"]["w"].dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(new_p["w"])))

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in [0, 5, 10, 100]]
        assert lrs[0] == 0.0
        assert abs(lrs[1] - 0.5) < 1e-6
        assert lrs[2] == pytest.approx(1.0, abs=1e-2)
        assert lrs[3] == pytest.approx(cfg.min_lr_ratio, abs=1e-2)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=2)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "opt_state": {"step": jnp.asarray(7)}}
        ckpt.save(7, state, blocking=True)
        restored, step = ckpt.restore(state)
        assert step == 7
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
        )

    def test_gc_keeps_last_k(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=2)
        state = {"x": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            ckpt.save(s, state, blocking=True)
        assert ckpt.all_steps() == [3, 4]

    def test_async_write_overlaps(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        state = {"x": jnp.zeros((256, 256))}
        ckpt.save(1, state)  # non-blocking
        ckpt.wait()
        assert ckpt.latest_step() == 1

    def test_atomicity_no_partial_dirs(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(3, {"x": jnp.ones(4)}, blocking=True)
        names = os.listdir(tmp_path)
        assert all(not n.endswith(".tmp0") for n in names)

    def test_crashed_write_tmp_dirs_never_restore(self, tmp_path):
        """A crash mid-write leaves ``step_N.tmpP`` for whatever process
        index P was writing — ``all_steps`` must skip them all, even
        with a complete-looking manifest inside (regression: only
        ``.tmp0`` used to be filtered)."""
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(2, {"x": jnp.ones(4)}, blocking=True)
        for proc in (0, 3):
            crashed = tmp_path / f"step_{9:08d}.tmp{proc}"
            crashed.mkdir()
            (crashed / "manifest.json").write_text(
                '{"step": 9, "process": %d}' % proc
            )
        assert ckpt.all_steps() == [2]
        assert ckpt.latest_step() == 2
        restored, step = ckpt.restore({"x": jnp.ones(4)})
        assert step == 2


class TestFaultTolerance:
    def _mini_step(self):
        def step(params, opt, batch):
            params = {"w": params["w"] - 0.1 * batch["g"]}
            return params, opt, {"loss": jnp.sum(params["w"] ** 2)}
        return step

    def test_restart_recovers_and_replays(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        loop = ResilientLoop(
            self._mini_step(), ckpt,
            FaultConfig(checkpoint_every=2, max_restarts=2),
        )
        crashed = {"done": False}

        def injector(step):
            if step == 3 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")

        params, _, step, history = loop.run(
            {"w": jnp.ones(2)}, {}, lambda s: {"g": jnp.ones(2)},
            num_steps=5, fail_injector=injector,
        )
        assert step == 5
        assert loop.stats["restarts"] == 1
        # deterministic data → same final state as a clean run
        clean = ResilientLoop(self._mini_step(), Checkpointer(str(tmp_path) + "2"),
                              FaultConfig(checkpoint_every=100))
        params_clean, _, _, _ = clean.run(
            {"w": jnp.ones(2)}, {}, lambda s: {"g": jnp.ones(2)}, num_steps=5
        )
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(params_clean["w"]))

    def test_restart_history_counts_each_step_once(self, tmp_path):
        """Regression: replayed steps used to append duplicate history
        entries (and inflate stats["steps"]).  After a crash at step 3
        restores the step-2 checkpoint, steps 2..3 re-run — history must
        still record each step exactly once."""
        ckpt = Checkpointer(str(tmp_path))
        loop = ResilientLoop(
            self._mini_step(), ckpt,
            FaultConfig(checkpoint_every=2, max_restarts=2),
        )
        crashed = {"done": False}

        def injector(step):
            if step == 3 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")

        _, _, step, history = loop.run(
            {"w": jnp.ones(2)}, {}, lambda s: {"g": jnp.ones(2)},
            num_steps=5, fail_injector=injector,
        )
        assert step == 5
        assert [h["step"] for h in history] == [0, 1, 2, 3, 4]
        assert loop.stats["steps"] == 5

    def test_restart_before_any_checkpoint_truncates_history(self, tmp_path):
        """Crash before the first checkpoint restarts from the initial
        state — every completed step replays, so history resets too."""
        loop = ResilientLoop(
            self._mini_step(), Checkpointer(str(tmp_path)),
            FaultConfig(checkpoint_every=100, max_restarts=2),
        )
        crashed = {"done": False}

        def injector(step):
            if step == 2 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("early failure")

        _, _, step, history = loop.run(
            {"w": jnp.ones(2)}, {}, lambda s: {"g": jnp.ones(2)},
            num_steps=4, fail_injector=injector,
        )
        assert step == 4
        assert [h["step"] for h in history] == [0, 1, 2, 3]
        assert loop.stats["steps"] == 4 and loop.stats["restarts"] == 1

    def test_straggler_detection(self, tmp_path):
        seen = []
        loop = ResilientLoop(
            self._mini_step(), Checkpointer(str(tmp_path)),
            FaultConfig(straggler_factor=1.5),
            on_straggler=lambda s, ratio: seen.append((s, ratio)),
        )
        # manually feed step times
        loop._track_time(0, 0.1)
        loop._track_time(1, 0.1)
        loop._track_time(2, 1.0)  # straggler
        assert loop.stats["stragglers"] == 1 and seen[0][0] == 2

    def test_heartbeat_written(self, tmp_path):
        hb = str(tmp_path / "hb")
        loop = ResilientLoop(
            self._mini_step(), Checkpointer(str(tmp_path / "c")),
            FaultConfig(heartbeat_path=hb, checkpoint_every=100),
        )
        loop.run({"w": jnp.ones(2)}, {}, lambda s: {"g": jnp.ones(2)}, num_steps=2)
        assert os.path.exists(hb)


class TestDataPipeline:
    def test_step_keyed_determinism(self):
        cfg = DataConfig(seq_len=16, global_batch=4, seed=3)
        src = make_source(cfg)
        b1, b2 = src.batch(5), src.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = src.batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(seq_len=16, global_batch=2)
        b = make_source(cfg).batch(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)

    def test_prefetch_iterator_order_and_seek(self):
        cfg = DataConfig(seq_len=8, global_batch=2)
        src = make_source(cfg)
        it = PrefetchIterator(src, start_step=0, depth=2)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], src.batch(0)["tokens"])
        it.seek(10)
        np.testing.assert_array_equal(next(it)["tokens"], src.batch(10)["tokens"])

    def test_host_shard_slices_rows(self):
        batch = {"tokens": np.arange(32).reshape(8, 4)}
        shard = host_shard(batch, process_index=1, process_count=2)
        np.testing.assert_array_equal(shard["tokens"], batch["tokens"][4:])

    def test_file_source(self, tmp_path):
        path = str(tmp_path / "toks.bin")
        np.arange(10000, dtype=np.uint16).tofile(path)
        cfg = DataConfig(seq_len=8, global_batch=2, kind="file", path=path)
        b = make_source(cfg).batch(1)
        assert b["tokens"].shape == (2, 8)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


class TestCompression:
    @hypothesis.given(st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_error_feedback_preserves_sum(self, seed):
        """Over many steps, Σ compressed ≈ Σ true gradients (EF property)."""
        rng = np.random.default_rng(seed)
        grads = [rng.normal(size=(64,)).astype(np.float32) * 1e-3 for _ in range(30)]
        err = None
        total_q = np.zeros(64, np.float64)
        for g in grads:
            q, err = compression.compress_decompress({"g": jnp.asarray(g)}, err)
            total_q += np.asarray(q["g"], np.float64)
        total = np.sum(grads, axis=0)
        residual = np.asarray(err["g"])
        np.testing.assert_allclose(total_q + residual, total, atol=1e-5)

    def test_compression_is_bf16_quantized(self):
        g = {"g": jnp.asarray([1.0 + 1e-4])}
        q, err = compression.compress_decompress(g, None)
        assert float(q["g"][0]) != float(g["g"][0])  # rounding happened
        assert abs(float(q["g"][0] + err["g"][0]) - float(g["g"][0])) < 1e-9
