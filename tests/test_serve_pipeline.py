"""The Stream-shaped serving gate: pipelined decode bit-identity.

One subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4
runs a mixed prefill/decode workload (more requests than slots, ragged
prompt lengths, mixed budgets — so slots retire and admit mid-flight)
through the sequential reference ``Engine`` and through ``StreamEngine``
under ``FutureEvaluator`` on 4 devices for both gpipe and interleaved
(V=2) schedules.  Greedy outputs must match token for token — the
paper's monad substitution applied to serving: same program text, Lazy
swapped for Future, results bit-identical.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidevice

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro import compat
from repro.configs.base import DecodePipelineConfig
from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import Engine, ServeConfig, StreamEngine

sc = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=8)
params = init_params(jax.random.PRNGKey(0), T.model_layout(sc))
mesh = compat.make_mesh((4,), ("pod",), axis_types=(compat.AxisType.Auto,))

scfg = ServeConfig(max_batch=8, max_len=64, prefill_chunk=4, max_new_tokens=6)
rng = np.random.default_rng(7)
prompts = [rng.integers(1, sc.vocab_size, size=int(rng.integers(1, 9)))
           for _ in range(14)]
budgets = [int(b) for b in rng.integers(1, 8, size=14)]

ref = Engine(params, sc, scfg)
reqs_ref = [ref.submit(p, b) for p, b in zip(prompts, budgets)]
ref.run_until_drained()

for sched, v, cells, m in [("gpipe", 1, 8, 8), ("interleaved", 2, 8, 4)]:
    pcfg = DecodePipelineConfig(num_cells=cells, microbatches=m,
                                schedule=sched, interleave=v,
                                round_steps=4, admit_per_round=4)
    eng = StreamEngine(params, sc, scfg, pcfg, mesh=mesh)
    reqs = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
    done = eng.run_until_drained()
    ok = len(done) == len(prompts) and all(
        rb.done and ra.out_tokens == rb.out_tokens
        for ra, rb in zip(reqs_ref, reqs)
    )
    print(f"SERVE_{sched.upper()}", ok)

# temperature sampling: per-request RNG identical under the pipeline
scfg_t = ServeConfig(max_batch=8, max_len=64, prefill_chunk=4,
                     max_new_tokens=5, temperature=0.9, seed=11)
ref_t = Engine(params, sc, scfg_t)
rt_ref = [ref_t.submit(p, b) for p, b in zip(prompts[:10], budgets[:10])]
ref_t.run_until_drained()
eng_t = StreamEngine(params, sc, scfg_t, DecodePipelineConfig(
    num_cells=8, microbatches=8, schedule="gpipe", round_steps=4,
    admit_per_round=4), mesh=mesh)
rt = [eng_t.submit(p, b) for p, b in zip(prompts[:10], budgets[:10])]
eng_t.run_until_drained()
print("SERVE_TEMPERATURE", all(
    a.out_tokens == b.out_tokens for a, b in zip(rt_ref, rt)))

# emit split: the round program's only logits-width matmul must live
# behind the plan-keyed emit conditional (region isolation in the SPMD
# module), and the plan's emit column must be zero on every non-final
# device — together: no non-final device's executed tick body contains
# the LM head.
from repro.roofline.hlo_parse import head_matmul_conditional_only
for sched, v, cells, m in [("gpipe", 1, 8, 8), ("interleaved", 2, 8, 4)]:
    pcfg_h = DecodePipelineConfig(num_cells=cells, microbatches=m,
                                  schedule=sched, interleave=v,
                                  round_steps=4, admit_per_round=4)
    eng_h = StreamEngine(params, sc, scfg, pcfg_h, mesh=mesh)
    adm_h, _ = eng_h._plan_admissions(pcfg_h.round_steps)
    ii, ov, ap = eng_h._build_round_inputs(adm_h)
    txt = eng_h._round.lower(
        {**eng_h.cell_consts, "adm": ap}, eng_h.cell_states, ii, ov
    ).compile().as_text()
    guarded = head_matmul_conditional_only(txt, sc.vocab_size)
    plan = eng_h.evaluator.plan_for(
        pcfg_h.round_steps * m, (0, 0), feedback_lag=m)
    last_only = bool((plan.emit[:, :3] == 0).all()) and int(plan.emit.sum()) > 0
    print(f"EMIT_SPLIT_{sched.upper()}", guarded and last_only)
"""


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=1500,
        stdin=subprocess.DEVNULL,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return dict(
        line.split(None, 1) for line in proc.stdout.strip().splitlines()
    )


def test_pipelined_gpipe_bit_identical(report):
    assert report["SERVE_GPIPE"].startswith("True")


def test_pipelined_interleaved_bit_identical(report):
    assert report["SERVE_INTERLEAVED"].startswith("True")


def test_pipelined_temperature_sampling_identical(report):
    assert report["SERVE_TEMPERATURE"].startswith("True")


def test_emit_split_head_matmul_last_stage_only_gpipe(report):
    # acceptance: the LM head is conditional-guarded in the compiled
    # round HLO and the plan's emit column fires only on device D-1
    assert report["EMIT_SPLIT_GPIPE"].startswith("True")


def test_emit_split_head_matmul_last_stage_only_interleaved(report):
    assert report["EMIT_SPLIT_INTERLEAVED"].startswith("True")
