"""The Stream-shaped serving gate: pipelined decode bit-identity.

One subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4
runs a mixed prefill/decode workload (more requests than slots, ragged
prompt lengths, mixed budgets — so slots retire and admit mid-flight)
through the sequential reference ``Engine`` and through ``StreamEngine``
under ``FutureEvaluator`` on 4 devices for both gpipe and interleaved
(V=2) schedules.  Greedy outputs must match token for token — the
paper's monad substitution applied to serving: same program text, Lazy
swapped for Future, results bit-identical.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidevice

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro import compat
from repro.configs.base import DecodePipelineConfig
from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import Engine, ServeConfig, StreamEngine

sc = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=8)
params = init_params(jax.random.PRNGKey(0), T.model_layout(sc))
mesh = compat.make_mesh((4,), ("pod",), axis_types=(compat.AxisType.Auto,))

scfg = ServeConfig(max_batch=8, max_len=64, prefill_chunk=4, max_new_tokens=6)
rng = np.random.default_rng(7)
prompts = [rng.integers(1, sc.vocab_size, size=int(rng.integers(1, 9)))
           for _ in range(14)]
budgets = [int(b) for b in rng.integers(1, 8, size=14)]

ref = Engine(params, sc, scfg)
reqs_ref = [ref.submit(p, b) for p, b in zip(prompts, budgets)]
ref.run_until_drained()

for sched, v, cells, m in [("gpipe", 1, 8, 8), ("interleaved", 2, 8, 4)]:
    pcfg = DecodePipelineConfig(num_cells=cells, microbatches=m,
                                schedule=sched, interleave=v,
                                round_steps=4, admit_per_round=4)
    eng = StreamEngine(params, sc, scfg, pcfg, mesh=mesh)
    reqs = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
    done = eng.run_until_drained()
    ok = len(done) == len(prompts) and all(
        rb.done and ra.out_tokens == rb.out_tokens
        for ra, rb in zip(reqs_ref, reqs)
    )
    print(f"SERVE_{sched.upper()}", ok)

# temperature sampling: per-request RNG identical under the pipeline
scfg_t = ServeConfig(max_batch=8, max_len=64, prefill_chunk=4,
                     max_new_tokens=5, temperature=0.9, seed=11)
ref_t = Engine(params, sc, scfg_t)
rt_ref = [ref_t.submit(p, b) for p, b in zip(prompts[:10], budgets[:10])]
ref_t.run_until_drained()
eng_t = StreamEngine(params, sc, scfg_t, DecodePipelineConfig(
    num_cells=8, microbatches=8, schedule="gpipe", round_steps=4,
    admit_per_round=4), mesh=mesh)
rt = [eng_t.submit(p, b) for p, b in zip(prompts[:10], budgets[:10])]
eng_t.run_until_drained()
print("SERVE_TEMPERATURE", all(
    a.out_tokens == b.out_tokens for a, b in zip(rt_ref, rt)))

# emit split: the round program's only logits-width matmul must live
# behind the plan-keyed emit conditional (region isolation in the SPMD
# module), and the plan's emit column must be zero on every non-final
# device — together: no non-final device's executed tick body contains
# the LM head.
from repro.roofline.hlo_parse import head_matmul_conditional_only


def round_text(eng, pcfg):
    adm, _ = eng._plan_admissions(pcfg.round_steps)
    ii, ov, ap = eng._build_round_inputs(adm)
    return eng._round.lower(
        {**eng.cell_consts, "adm": ap}, eng.cell_states, ii, ov
    ).compile().as_text()


texts = {}
for sched, v, cells, m in [("gpipe", 1, 8, 8), ("interleaved", 2, 8, 4)]:
    pcfg_h = DecodePipelineConfig(num_cells=cells, microbatches=m,
                                  schedule=sched, interleave=v,
                                  round_steps=4, admit_per_round=4)
    eng_h = StreamEngine(params, sc, scfg, pcfg_h, mesh=mesh)
    txt = round_text(eng_h, pcfg_h)
    texts[sched] = txt
    guarded = head_matmul_conditional_only(txt, sc.vocab_size)
    plan = eng_h.evaluator.plan_for(
        pcfg_h.round_steps * m, (0, 0), feedback_lag=m)
    last_only = bool((plan.emit[:, :3] == 0).all()) and int(plan.emit.sum()) > 0
    print(f"EMIT_SPLIT_{sched.upper()}", guarded and last_only)

# Pallas decode cells: same pipelined battery with the fused
# decode-attention + emit kernels (interpret-emulated on CPU) — tokens
# must stay bit-identical to the sequential xla reference.
pcfg_p = DecodePipelineConfig(num_cells=8, microbatches=8, schedule="gpipe",
                              round_steps=4, admit_per_round=4,
                              kernels="pallas")
eng_p = StreamEngine(params, sc, scfg, pcfg_p, mesh=mesh)
reqs_p = [eng_p.submit(p, b) for p, b in zip(prompts, budgets)]
done_p = eng_p.run_until_drained()
print("SERVE_GPIPE_PALLAS", len(done_p) == len(prompts) and all(
    rb.done and ra.out_tokens == rb.out_tokens
    for ra, rb in zip(reqs_ref, reqs_p)))

# Structural pins on the compiled round HLO (positive + negative
# controls): the fused-kernel name scopes appear only in the pallas
# module; the pallas steady tick carries at most half the xla module's
# slab-sized cache writes (the per-layer K/V slab materializations are
# gone — what remains is admission row traffic); and the LM head stays
# conditional-guarded with the fused emit in place.
from repro.kernels.decode_attention.ops import FUSION_SCOPE as ATTN_SCOPE
from repro.kernels.emit_norm_logits.ops import FUSION_SCOPE as EMIT_SCOPE
from repro.roofline.hlo_parse import fused_region_present, slab_scatter_counts

txt_xla = texts["gpipe"]
txt_pallas = round_text(eng_p, pcfg_p)
print("HLO_MARKER_PALLAS", fused_region_present(txt_pallas, ATTN_SCOPE)
      and fused_region_present(txt_pallas, EMIT_SCOPE))
print("HLO_MARKER_XLA_ABSENT", not fused_region_present(txt_xla, ATTN_SCOPE)
      and not fused_region_present(txt_xla, EMIT_SCOPE))
mb = scfg.max_batch // pcfg_p.microbatches
slab = (mb * scfg.max_len * sc.num_kv_heads * sc.head_dim
        * jax.numpy.dtype(sc.dtype).itemsize)
tot_x, ung_x = slab_scatter_counts(txt_xla, slab)
tot_p, ung_p = slab_scatter_counts(txt_pallas, slab)
# The group body's K+V slab materializations (one static pair — the
# layer scan counts its body once) must be gone; the writes both modes
# share are admission-buffer row traffic, which stays.
print("HLO_SLAB_SCATTER", tot_x > 0 and tot_p <= tot_x - 2
      and ung_p <= ung_x, f"xla={tot_x}/{ung_x} pallas={tot_p}/{ung_p}")
print("HLO_HEAD_GUARD_PALLAS",
      head_matmul_conditional_only(txt_pallas, sc.vocab_size))
"""


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=1500,
        stdin=subprocess.DEVNULL,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return dict(
        line.split(None, 1) for line in proc.stdout.strip().splitlines()
    )


def test_pipelined_gpipe_bit_identical(report):
    assert report["SERVE_GPIPE"].startswith("True")


def test_pipelined_interleaved_bit_identical(report):
    assert report["SERVE_INTERLEAVED"].startswith("True")


def test_pipelined_temperature_sampling_identical(report):
    assert report["SERVE_TEMPERATURE"].startswith("True")


def test_emit_split_head_matmul_last_stage_only_gpipe(report):
    # acceptance: the LM head is conditional-guarded in the compiled
    # round HLO and the plan's emit column fires only on device D-1
    assert report["EMIT_SPLIT_GPIPE"].startswith("True")


def test_emit_split_head_matmul_last_stage_only_interleaved(report):
    assert report["EMIT_SPLIT_INTERLEAVED"].startswith("True")


def test_pipelined_pallas_bit_identical(report):
    # kernels="pallas" through the 4-device FutureEvaluator: fused decode
    # attention + emit epilogue, tokens identical to the xla reference
    assert report["SERVE_GPIPE_PALLAS"].startswith("True")


def test_fusion_markers_present_in_pallas_hlo_only(report):
    # positive control: both kernel name scopes in the pallas module...
    assert report["HLO_MARKER_PALLAS"].startswith("True")
    # ...negative control: neither in the xla module
    assert report["HLO_MARKER_XLA_ABSENT"].startswith("True")


def test_pallas_round_drops_steady_tick_slab_writes(report):
    # the layer-scan body's K/V slab materializations are gone from the
    # pallas round; remaining slab-sized writes are admission traffic
    # both modes share
    assert report["HLO_SLAB_SCATTER"].startswith("True")


def test_head_matmul_stays_guarded_under_pallas(report):
    assert report["HLO_HEAD_GUARD_PALLAS"].startswith("True")
