"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import pytest

from _hypothesis_stub import hypothesis, st  # skips @given tests offline
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_chunked_pallas
from repro.kernels.ssd.ref import ssd_ref
from repro.models.ssm import ssd_chunked


FLASH_CASES = [
    # b, h, kv, sq, sk, dh, causal, dtype, tol
    (2, 4, 4, 128, 128, 64, True, jnp.float32, 2e-5),
    (1, 8, 2, 256, 256, 64, True, jnp.float32, 2e-5),
    (1, 4, 4, 128, 128, 128, True, jnp.bfloat16, 2e-2),
    (2, 2, 1, 128, 256, 64, False, jnp.float32, 2e-5),
    (1, 16, 4, 256, 256, 64, True, jnp.bfloat16, 2e-2),
    (1, 2, 2, 384, 384, 32, True, jnp.float32, 2e-5),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
def test_flash_attention_vs_ref(case):
    b, h, kv, sq, sk, dh, causal, dt, tol = case
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(b, h, sq, dh)), dt)
    k = jnp.asarray(rng.normal(size=(b, kv, sk, dh)), dt)
    v = jnp.asarray(rng.normal(size=(b, kv, sk, dh)), dt)
    out = flash_attention_bhsd(
        q, k, v, causal=causal, block_q=128, block_k=128, interpret=True
    )
    ref = attention_ref(q, k, v, causal=causal)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, err


def test_flash_wrapper_layout_matches_model_attention():
    from repro.models.layers import attention_dense

    rng = np.random.default_rng(1)
    b, s, h, kv, dh = 2, 256, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True, block_q=128, block_k=128)
    ref = attention_dense(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


SSD_CASES = [
    # b, s, h, p, g, n, chunk
    (2, 128, 4, 64, 1, 128, 32),
    (1, 256, 8, 64, 2, 64, 64),
    (2, 64, 2, 32, 1, 32, 16),
    (1, 128, 4, 64, 4, 32, 128),
]


@pytest.mark.parametrize("case", SSD_CASES, ids=str)
@pytest.mark.parametrize("recurrence", ["scan", "associative"])
def test_ssd_kernel_vs_ref(case, recurrence):
    b, s, h, p, g, n, chunk = case
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32) / np.sqrt(n)
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32) / np.sqrt(n)
    dsk = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    y_ref, s_ref = ssd_ref(x, dt, a, bm, cm, dsk)
    y, s_fin = ssd_chunked_pallas(
        x, dt, a, bm, cm, dsk, chunk=chunk, interpret=True, recurrence=recurrence
    )
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref))) < 2e-3
    assert float(jnp.max(jnp.abs(s_fin - s_ref))) < 2e-3


def test_ssd_model_scan_matches_ref():
    rng = np.random.default_rng(3)
    b, s, h, p, g, n = 2, 96, 4, 32, 1, 64
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32) / np.sqrt(n)
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32) / np.sqrt(n)
    dsk = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    y_ref, s_ref = ssd_ref(x, dt, a, bm, cm, dsk)
    y, s_fin = ssd_chunked(x, dt, a, bm, cm, dsk, chunk=32)
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref))) < 2e-3
    assert float(jnp.max(jnp.abs(s_fin - s_ref))) < 2e-3


def test_ssd_initial_state_continuation():
    """Splitting a sequence across two calls must equal one call (the
    stream's carried value handoff — checkpoint/restart of the cell chain)."""
    rng = np.random.default_rng(5)
    b, s, h, p, g, n = 1, 128, 2, 32, 1, 32
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32) / np.sqrt(n)
    cm = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32) / np.sqrt(n)
    dsk = jnp.zeros((h,), jnp.float32)
    y_full, s_full = ssd_chunked(x, dt, a, bm, cm, dsk, chunk=32)
    half = s // 2
    y1, s1 = ssd_chunked(
        x[:, :half], dt[:, :half], a, bm[:, :half], cm[:, :half], dsk, chunk=32
    )
    y2, s2 = ssd_chunked(
        x[:, half:], dt[:, half:], a, bm[:, half:], cm[:, half:], dsk,
        chunk=32, initial_state=s1,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
        atol=1e-4,
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


@hypothesis.given(
    st.integers(1, 3), st.integers(1, 4),
    st.sampled_from([16, 32]), st.sampled_from([16, 32]),
)
@hypothesis.settings(max_examples=8, deadline=None)
def test_ssd_associative_combine_is_associative(b, h, n, p):
    """The (decay, state) semigroup underlying the beyond-paper recurrence."""
    from repro.kernels.ssd.ops import _combine

    rng = np.random.default_rng(b * 100 + h)
    def elem():
        return (
            jnp.asarray(rng.uniform(0.1, 1.0, size=(b, h)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, h, n, p)), jnp.float32),
        )

    x, y, z = elem(), elem(), elem()
    left = _combine(_combine(x, y), z)
    right = _combine(x, _combine(y, z))
    for l, r in zip(left, right):
        np.testing.assert_allclose(np.asarray(l), np.asarray(r), rtol=1e-5, atol=1e-5)
