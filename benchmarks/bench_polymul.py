"""Paper Table 1 / Figure 4: sparse polynomial multiplication.

Rows: stream / stream_big (Lazy, Future×1, Future×2) and the
parallel-collections control list / list_big (times_dense).  Coefficient
footprint via limb count; ``stream_big`` multiplies by 100000000001 as in
the paper.  quick mode uses (1+x+y+z)^6; --paper-scale uses ^20 ×(^20+1)
(the Fateman case the paper cites).
"""
from __future__ import annotations

import jax

from benchmarks._util import csv_row, run_with_devices, timed
from repro.algorithms import polynomial as poly

PAR_SCRIPT = """
import time, jax
from repro import compat
from repro.algorithms import polynomial as poly
from repro.core.stream import FutureEvaluator
power, limbs, big, tpc, xch, acc = {power}, {limbs}, {big}, {tpc}, {xch}, {acc}
cap = {cap}
x = poly.fateman_poly(power, cap, limbs, big_factor=big)
y = poly.fateman_poly(power, cap, limbs, big_factor=big)
mesh = compat.make_mesh((jax.device_count(),), ("pod",),
                        axis_types=(compat.AxisType.Auto,))
ev = FutureEvaluator(mesh, "pod")
fn = jax.jit(lambda x, y: poly.times(x, y, evaluator=ev, num_x_chunks=xch,
                                     terms_per_cell=tpc, acc_capacity=acc))
out = fn(x, y); jax.block_until_ready(out.coeffs)
t0 = time.perf_counter()
out = fn(x, y); jax.block_until_ready(out.coeffs)
print(time.perf_counter() - t0)
"""


def _sizes(power: int, tpc: int, xch: int):
    n_terms = (power + 3) * (power + 2) * (power + 1) // 6
    quantum = tpc * max(2, xch)
    cap = -(-n_terms // quantum) * quantum
    p2 = 2 * power
    acc = 1 << ((p2 + 3) * (p2 + 2) * (p2 + 1) // 6 - 1).bit_length()
    return cap, acc


def run(quick: bool = True, paper_scale: bool = False):
    rows = []
    power = 20 if paper_scale else (6 if quick else 10)
    tpc, xch = 8, 4
    cap, acc = _sizes(power, tpc, xch)
    for name, limbs, big in (("stream", 4, 1), ("stream_big", 12, 100000000001)):
        x = poly.fateman_poly(power, cap, limbs, big_factor=big)
        y = poly.fateman_poly(power, cap, limbs, big_factor=big)
        fn = jax.jit(
            lambda x, y: poly.times(
                x, y, num_x_chunks=xch, terms_per_cell=tpc, acc_capacity=acc
            )
        )
        t_seq, out = timed(fn, x, y, repeats=3)
        if quick:  # correctness only at small scale (oracle is O(n^2) python)
            assert poly.to_dict(out) == poly.reference_product(
                poly.to_dict(x), poly.to_dict(y)
            )
        rows.append(csv_row(f"{name}_seq", t_seq, f"power={power},limbs={limbs}"))
        for nd in (1, 2):
            stdout = run_with_devices(
                PAR_SCRIPT.format(power=power, limbs=limbs, big=big,
                                  tpc=tpc, xch=xch, acc=acc, cap=cap),
                nd,
            )
            rows.append(csv_row(
                f"{name}_par{nd}", float(stdout.strip().splitlines()[-1]),
                f"power={power},limbs={limbs}",
            ))
        # the paper's `list` control: data-parallel dense outer product
        fn_d = jax.jit(lambda x, y: poly.times_dense(x, y, capacity=acc))
        t_dense, _ = timed(fn_d, x, y, repeats=3)
        list_name = "list" if name == "stream" else "list_big"
        rows.append(csv_row(f"{list_name}", t_dense, f"power={power},limbs={limbs}"))
    return rows


if __name__ == "__main__":
    import sys

    for row in run(quick=True, paper_scale="--paper-scale" in sys.argv):
        print(row)
