"""Training step time: autodiff vs planned backward, per schedule x M.

The tentpole claim of the planned backward is *scheduling*, not raw
speed: the combined plan (repro.core.schedules.build_combined_plan)
makes the backward first-class tick work and bounds 1F1B's stash at
min(S, M) — but the two paths must also stay in the same wall-clock
ballpark, and neither may silently regress.  This suite times one
jitted ``value_and_grad`` step of the same 16-cell model under every
(schedule, backward, M) cell, paired inside one subprocess exactly like
bench_pipeline (machine drift hits every cell equally; see that
module's docstring for the pairing rationale).

``benchmarks/run.py --suite train`` persists the records to
BENCH_train.json; ``--check`` then diffs a fresh sweep against it and
fails on >tolerance wall-clock regression per cell — the planned
backward is gated the day it lands.  Each record also carries the
plan-level peak-stash counts (planned vs autodiff) so the memory story
is in the artifact, not just the test suite.
"""
from __future__ import annotations

from benchmarks._util import csv_row, run_with_devices
from repro.core.chunking import schedule_peak_items

# (schedule, devices, interleave): the two true-1F1B-relevant layouts.
SWEEP = [
    ("gpipe", 4, 1),
    ("one_f_one_b", 4, 1),
]
BACKWARDS = ("autodiff", "planned")

SCRIPT = """
import time, jax, jax.numpy as jnp
from repro import compat
from repro.core import StreamProgram, FutureEvaluator, evaluate
M, D, ROWS = {micro}, {dim}, {rows}
CELLS = 16
W = jax.random.normal(jax.random.PRNGKey(0), (CELLS, D, D)) / D**0.5
items = jax.random.normal(jax.random.PRNGKey(1), (M, ROWS // M, D))
def loss(W, items, ev):
    prog = StreamProgram(lambda w, x: (w, jnp.tanh(x @ w)), W, CELLS,
                         mutable_state=False, remat=True)
    return jnp.sum(evaluate(prog, items, ev)[1] ** 2)
runs = {{}}
for name, ndev, v in {sweep!r}:
    mesh = compat.make_mesh((ndev,), ("pod",), devices=jax.devices()[:ndev])
    for bwd in {backwards!r}:
        ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v,
                             backward=bwd)
        fn = jax.jit(jax.value_and_grad(
            lambda W, ev=ev: loss(W, items, ev)))
        jax.block_until_ready(fn(W))  # compile
        runs[(name, bwd)] = fn
best = {{k: 1e9 for k in runs}}
for _ in range(5):  # interleave repeats across cells: paired timing
    for k, fn in runs.items():
        t0 = time.perf_counter()
        jax.block_until_ready(fn(W))
        best[k] = min(best[k], time.perf_counter() - t0)
for (name, bwd), t in best.items():
    print(name, bwd, t)
"""


def run(quick: bool = True):
    rows_csv, records = [], []
    dim, rows = (128, 2048) if quick else (256, 4096)
    for micro in (4, 8):
        out = run_with_devices(
            SCRIPT.format(
                micro=micro, dim=dim, rows=rows, sweep=SWEEP,
                backwards=BACKWARDS,
            ),
            4,
        )
        timings = {}
        for line in out.strip().splitlines()[-len(SWEEP) * len(BACKWARDS):]:
            name, bwd, t = line.split()
            timings[(name, bwd)] = float(t)
        for schedule, ndev, interleave in SWEEP:
            for bwd in BACKWARDS:
                t = timings[(schedule, bwd)]
                peak = schedule_peak_items(
                    schedule, ndev, micro, interleave, backward=bwd
                )
                rows_csv.append(
                    csv_row(
                        f"train_{schedule}_{bwd}_m{micro}",
                        t,
                        f"peak_stash={peak}/{micro},devices={ndev}",
                    )
                )
                records.append(
                    {
                        "schedule": schedule,
                        "backward": bwd,
                        "devices": ndev,
                        "interleave": interleave,
                        "num_microbatches": micro,
                        "dim": dim,
                        "rows": rows,
                        "measured_seconds": t,
                        "peak_stash_items": peak,
                    }
                )
    run.records = records  # picked up by benchmarks.run for BENCH_train.json
    return rows_csv


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
