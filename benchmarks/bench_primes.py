"""Paper Table 1 / Figure 3: the prime sieve, seq vs par(1) vs par(2).

``primes`` and ``primes_x3`` follow the paper (limits 20000 / 60000);
``quick`` mode shrinks the limits so the full harness stays snappy on one
core.  seq = Lazy monad in-process; par(N) = Future monad in a fresh
process with N virtual devices (the paper's 'available processors').
"""
from __future__ import annotations

import jax

from benchmarks._util import csv_row, run_with_devices, timed
from repro.algorithms import sieve

PAR_SCRIPT = """
import time, numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.algorithms import sieve
from repro.core.stream import FutureEvaluator
limit, block, ppc, cells = {limit}, {block}, {ppc}, {cells}
mesh = compat.make_mesh((jax.device_count(),), ("pod",),
                        axis_types=(compat.AxisType.Auto,))
ev = FutureEvaluator(mesh, "pod")
run = jax.jit(lambda items_unused: 0)  # warm placeholder
p, c = sieve.run_sieve(limit, block_size=block, primes_per_cell=ppc,
                       num_cells=cells, evaluator=ev)  # compile
jax.block_until_ready(p)
t0 = time.perf_counter()
p, c = sieve.run_sieve(limit, block_size=block, primes_per_cell=ppc,
                       num_cells=cells, evaluator=ev)
jax.block_until_ready(p)
print(time.perf_counter() - t0)
ref = sieve.reference_primes(limit)
pn = np.asarray(p)
assert int(c) == len(ref) and np.array_equal(pn[pn>0], ref), "wrong primes"
"""


def _cells(limit: int, ppc: int, devices: int) -> int:
    bound = int(sieve._pi_upper_bound(limit))
    cells = -(-bound // ppc)
    return -(-cells // devices) * devices  # divisible by device count


def run(quick: bool = True):
    rows = []
    cases = [("primes", 2000 if quick else 20000),
             ("primes_x3", 6000 if quick else 60000)]
    block, ppc = 256, 16
    for name, limit in cases:
        cells = _cells(limit, ppc, 2)
        seq_fn = lambda: sieve.run_sieve(
            limit, block_size=block, primes_per_cell=ppc, num_cells=cells
        )[0]
        t_seq, primes = timed(seq_fn, repeats=3)
        import numpy as np

        ref = sieve.reference_primes(limit)
        pn = np.asarray(primes)
        assert np.array_equal(pn[pn > 0], ref)
        rows.append(csv_row(f"{name}_seq", t_seq, f"limit={limit}"))
        for nd in (1, 2):
            out = run_with_devices(
                PAR_SCRIPT.format(limit=limit, block=block, ppc=ppc, cells=cells),
                nd,
            )
            t_par = float(out.strip().splitlines()[-1])
            rows.append(csv_row(f"{name}_par{nd}", t_par, f"limit={limit}"))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
