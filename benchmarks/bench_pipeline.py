"""Pipeline schedules: analytic model vs measured step time.

Fixes the *model* at 4 pipeline stages (16 cells) and M microbatches,
and lets each schedule realize those stages on its natural device
layout — gpipe / 1F1B span one device per stage (D=4, V=1); the
interleaved schedule assigns each of D=2 devices V=2 non-contiguous
stage groups.  That is the schedule's actual production trade: fewer
pipeline devices each owning interleaved chunks, cutting the per-device
bubble `h(D-1)/(V*M + h(D-1))` and matching device count to real
parallel lanes (this container has 2 cores, so 4 virtual devices
oversubscribe 2x while D=2 is genuine parallelism).

All layouts for a given M are timed back-to-back inside one subprocess,
interleaved across repeats, so machine drift hits every schedule
equally — unpaired measurements minutes apart would drown the bubble
effect in noise.  Work sizes are chosen so per-cell compute dominates
the ring rendezvous (~ms on CPU): the paper's Section 7 condition,
measured.  The modeled bubble/ticks come from the schedule-aware
chunking model (`schedule_ticks` / `schedule_bubble_fraction`); `run`
returns records that `benchmarks/run.py` persists to
BENCH_pipeline.json as the perf trajectory baseline.
"""
from __future__ import annotations

from benchmarks._util import csv_row, run_with_devices
from repro.core.chunking import schedule_bubble_fraction, schedule_ticks

# (schedule, devices, interleave): always devices * interleave == 4
# virtual stages of the same 16-cell model.
SWEEP = [
    ("gpipe", 4, 1),
    ("one_f_one_b", 4, 1),
    ("interleaved", 2, 2),
]

SCRIPT = """
import time, jax, jax.numpy as jnp
from repro import compat
from repro.core import StreamProgram, FutureEvaluator, evaluate
M, D, ROWS = {micro}, {dim}, {rows}
CELLS = 16  # 4 virtual stages x 4 cells, identical for every layout
W = jax.random.normal(jax.random.PRNGKey(0), (CELLS, D, D)) / D**0.5
prog = StreamProgram(lambda w, x: (w, jnp.tanh(x @ w)), W, CELLS,
                     mutable_state=False)
items = jax.random.normal(jax.random.PRNGKey(1), (M, ROWS // M, D))
runs = {{}}
for name, ndev, v in {sweep!r}:
    mesh = compat.make_mesh((ndev,), ("pod",), devices=jax.devices()[:ndev])
    ev = FutureEvaluator(mesh, "pod", schedule=name, interleave=v)
    fn = jax.jit(lambda items, ev=ev: evaluate(prog, items, ev)[1])
    jax.block_until_ready(fn(items))  # compile
    runs[name] = fn
best = {{name: 1e9 for name, _, _ in {sweep!r}}}
for _ in range(7):  # interleave repeats across schedules: paired timing
    for name, fn in runs.items():
        t0 = time.perf_counter()
        jax.block_until_ready(fn(items))
        best[name] = min(best[name], time.perf_counter() - t0)
for name, t in best.items():
    print(name, t)
"""


def run(quick: bool = True):
    rows_csv, records = [], []
    dim, rows = (256, 4096) if quick else (512, 8192)
    for micro in (1, 2, 4, 8, 16):
        out = run_with_devices(
            SCRIPT.format(micro=micro, dim=dim, rows=rows, sweep=SWEEP), 4
        )
        timings = dict(
            line.split() for line in out.strip().splitlines()[-len(SWEEP):]
        )
        for schedule, ndev, interleave in SWEEP:
            t = float(timings[schedule])
            frac = schedule_bubble_fraction(schedule, ndev, micro, interleave)
            ticks = schedule_ticks(schedule, ndev, micro, interleave)
            rows_csv.append(
                csv_row(
                    f"pipeline_{schedule}_m{micro}",
                    t,
                    f"bubble={frac:.3f},ticks={ticks},devices={ndev}"
                    + (f",V={interleave}" if interleave > 1 else ""),
                )
            )
            records.append(
                {
                    "schedule": schedule,
                    "devices": ndev,
                    "interleave": interleave,
                    "virtual_stages": ndev * interleave,
                    "num_microbatches": micro,
                    "dim": dim,
                    "rows": rows,
                    "measured_seconds": t,
                    "modeled_bubble": frac,
                    "modeled_ticks": ticks,
                }
            )
    run.records = records  # picked up by benchmarks.run for BENCH_pipeline.json
    return rows_csv


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
