"""Pipeline bubble: analytic model vs measured schedule ticks.

Runs the Future evaluator on 4 virtual devices (subprocess) over a sweep
of microbatch counts M at fixed total work, and compares the measured
step time against chunking.pipeline_step_time.  The derived field reports
the bubble fraction (S-1)/(M+S-1) and model/measured agreement.
"""
from __future__ import annotations

from benchmarks._util import csv_row, run_with_devices
from repro.core.chunking import bubble_fraction

SCRIPT = """
import time, jax, jax.numpy as jnp
from repro.core import StreamProgram, FutureEvaluator, evaluate
S, M, D = {stages}, {micro}, {dim}
mesh = jax.make_mesh((jax.device_count(),), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
W = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) / D**0.5
prog = StreamProgram(lambda w, x: (w, jnp.tanh(x @ w)), W, S,
                     mutable_state=False)
items = jax.random.normal(jax.random.PRNGKey(1), (M, 256 // M, D))
ev = FutureEvaluator(mesh, "pod")
run = jax.jit(lambda items: evaluate(prog, items, ev)[1])
out = run(items); jax.block_until_ready(out)
best = 1e9
for _ in range(3):
    t0 = time.perf_counter()
    out = run(items); jax.block_until_ready(out)
    best = min(best, time.perf_counter() - t0)
print(best)
"""


def run(quick: bool = True):
    rows = []
    stages, dim = 4, 256 if quick else 512
    for micro in (1, 2, 4, 8, 16):
        out = run_with_devices(
            SCRIPT.format(stages=stages, micro=micro, dim=dim), stages
        )
        t = float(out.strip().splitlines()[-1])
        frac = bubble_fraction(stages, micro)
        rows.append(csv_row(
            f"pipeline_m{micro}", t, f"bubble={frac:.3f},stages={stages}"
        ))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
