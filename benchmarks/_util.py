"""Benchmark helpers: wall timing + subprocess runs with N virtual devices."""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def timed(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall seconds over repeats (after warmup/compile)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def run_with_devices(script: str, num_devices: int, timeout: int = 1200) -> str:
    """Run a python snippet under N virtual CPU devices; return stdout.

    Used for par(1)/par(2) measurements (the paper's 'available processors'
    column) — jax fixes the device count at first init, so a fresh process
    is the only way to vary it.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=timeout,
        stdin=subprocess.DEVNULL,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return proc.stdout


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
