"""Serving throughput: Stream-shaped pipelined decode, schedule by schedule.

One subprocess with 2 virtual devices (this container has 2 cores — D=2
is genuine parallelism, matching bench_pipeline's layout choice) runs
the same continuous-batching workload through four engines back to back:

* ``stream_lazy`` — **the layer-sequential baseline**: ``StreamEngine``
  under ``LazyEvaluator`` — the identical ``Stream.feedback`` round
  program (same cells, same in-plan admissions, same emit) with layers
  evaluated sequentially on one device.  This is the paper's Lazy side.
* ``stream_gpipe`` / ``stream_interleaved`` — the same program under
  ``FutureEvaluator`` with the layer-group cells sharded over both
  devices.  The monad substitution is the *only* change; the measured
  gap (gpipe ~1.4x lazy on this container) is the pipelining win —
  per-layer-group latency hidden behind the ring hand-off.
* ``sequential`` — the monolithic reference ``Engine`` (one jitted
  ``decode_step`` per decode step).  On this 2-core container it stays
  fastest in absolute terms because XLA's *intra-op* threading already
  gives the single-device program both cores at near-perfect efficiency
  — device-level pipelining has no spare cores to recruit here, so its
  win shows against the layer-sequential schedule of the same program,
  not against intra-op parallelism.  On a real multi-chip pod the
  sequential engine cannot use the other chips at all; the stream
  schedules are the scaling path (the per-tick overheads measured here
  are CPU-emulation artifacts — on TPU the hand-off is an async
  collective-permute the issue-early/force-late ring overlaps).

Measured per (engine, batch): tokens/sec over a drain of 2x-oversubscribed
requests (so admissions churn mid-flight) and TTFT — a single request on
an idle engine, submit until its first token is caller-visible: one
chunked prefill, plus (stream engines only) their first round, since
control returns to the caller at round boundaries.

**Prefill-tail microbench** (the ``prefill_tail_*`` rows): a prompt of
``2*chunk - 1`` tokens exercises the worst ragged tail.  The padded-tail
path (one masked prefill call, logits read at the last real position) vs
the old per-token path (chunk-1 B=1 decode calls).  Representative run
on this container (chunk=16, smoke model): padded ~40 ms vs per-token
~490 ms — a ~12x TTFT win for short ragged prompts, since tail cost
used to scale with ``plen % chunk``.

**Chaos cells** (the ``chaos_*`` rows): the same drain under the
:class:`repro.serve.supervisor.ServeSupervisor` — once clean, once with
an injected mid-drain fault.  Recorded per cell: ``requests_lost``
(gated == 0), ``bitwise_equal`` to the clean run (gated True), and
``recovery_overhead_seconds`` (the snapshot/restore/replay cost).

``run`` returns records persisted to ``BENCH_serve.json`` — the serving
perf trajectory ``benchmarks/run.py --check`` gates on (tokens/sec may
not regress; chaos cells must keep zero loss; see run.py).
"""
from __future__ import annotations

import json

from benchmarks._util import csv_row, run_with_devices

# (label, schedule, devices, interleave, kernels); stream_lazy is the
# layer-sequential baseline the pipelined schedules are gated against.
# stream_lazy_pallas runs the same round program with the fused
# decode-attention + emit kernels — on CPU the Pallas interpreter
# emulates them (a while loop per grid point), so its tokens/sec is a
# correctness-under-load cell, not the fusion win; the roofline
# prediction recorded next to it is what the fusion buys on real HBM.
ENGINES = [
    ("sequential", "-", 1, 1, "xla"),
    ("stream_lazy", "lazy", 1, 1, "xla"),
    ("stream_lazy_pallas", "lazy", 1, 1, "pallas"),
    ("stream_gpipe", "gpipe", 2, 1, "xla"),
    ("stream_interleaved", "interleaved", 2, 2, "xla"),
]

# Container-class roofline constants for the predicted-tick record
# (directional: the achieved/predicted ratio is tracked, not the
# absolute).  ~2 CPU cores of f32 FMA and dual-channel DDR-class
# bandwidth; on TPU the same prediction uses the chip's specs.
CPU_PEAK_FLOPS = 5e10
CPU_HBM_BPS = 2e10

SCRIPT = """
import json, time, jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import DecodePipelineConfig
from repro.configs.registry import get_config, smoke_config
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve.engine import Engine, ServeConfig, StreamEngine

BATCH, REQUESTS, MAX_NEW, PLEN, CHUNK = {batch}, {requests}, {max_new}, {plen}, {chunk}
DIM, LAYERS, ROUND, MICRO = {dim}, {layers}, {round_steps}, {micro}
cfg = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=LAYERS)
if DIM:
    cfg = cfg.with_overrides(d_model=DIM, d_ff=2 * DIM, num_heads=8,
                             head_dim=DIM // 8, num_kv_heads=2,
                             vocab_size=2048)
params = init_params(jax.random.PRNGKey(0), T.model_layout(cfg))
mesh = compat.make_mesh((2,), ("pod",), devices=jax.devices()[:2])
scfg = ServeConfig(max_batch=BATCH, max_len=64, prefill_chunk=CHUNK,
                   max_new_tokens=MAX_NEW)

def build(label, schedule, devices, interleave, kernels):
    if label == "sequential":
        return Engine(params, cfg, scfg)
    pcfg = DecodePipelineConfig(
        num_cells=LAYERS, microbatches=MICRO,
        schedule=schedule if schedule != "lazy" else "gpipe",
        interleave=interleave, round_steps=ROUND, admit_per_round=4,
        kernels=kernels)
    m = None if schedule == "lazy" else mesh
    return StreamEngine(params, cfg, scfg, pcfg, mesh=m)

def workload(rng):
    return [rng.integers(1, cfg.vocab_size, size=PLEN) for _ in range(REQUESTS)]

results = {{}}
engines = {{label: build(label, s, d, v, kern)
           for label, s, d, v, kern in {engines!r}}}
# warmup: compile every engine's hot path on a small drain
for label, eng in engines.items():
    for p in workload(np.random.default_rng(1))[: BATCH]:
        eng.submit(p, 4)
    eng.run_until_drained()
# TTFT: one request on an idle engine, submit until its first token is
# visible to the caller.  For every engine the token is produced by the
# chunked prefill inside the first step(); the stream engines' number
# additionally includes their first round — that is their true
# caller-observed latency (control only returns at round boundaries).
for label, eng in engines.items():
    vals = []
    for _ in range(3):
        t0 = time.perf_counter()
        r = eng.submit(np.random.default_rng(3).integers(1, cfg.vocab_size, size=PLEN))
        while not r.out_tokens:
            eng.step()
        vals.append(time.perf_counter() - t0)
        eng.run_until_drained()
    results.setdefault(label, {{}})["ttft"] = min(vals)
# paired timing: interleave repeats across engines so drift hits all equally
for rep in range(3):
    for label, eng in engines.items():
        rng = np.random.default_rng(7)
        t0 = time.perf_counter()
        reqs = [eng.submit(p) for p in workload(rng)]
        eng.run_until_drained()
        wall = time.perf_counter() - t0
        total = sum(len(r.out_tokens) for r in reqs)
        results[label].setdefault("runs", []).append((wall, total))
for label in engines:
    walls, totals = zip(*results[label]["runs"])
    print("ENGINE", label, min(walls), results[label]["ttft"], totals[0])

# prefill ragged-tail microbench: padded masked chunk vs per-token decode
eng = engines["sequential"]
prompt = np.arange(1, 2 * CHUNK, dtype=np.int32)  # 2*CHUNK - 1: worst tail
from repro.serve.engine import Request
def padded():
    r = Request(uid=10**6, prompt=prompt, max_new_tokens=4)
    return eng._prefill_single(r)
def per_token():
    single = T.init_cache(cfg, 1, scfg.max_len)
    lg, single = eng._prefill(params, single, tokens=jnp.asarray(prompt[None, :CHUNK]), pos=0)
    for t in range(CHUNK, len(prompt)):
        lg, single = _dec(params, single, jnp.asarray(prompt[None, t]), jnp.full((1,), t, jnp.int32))
    return jax.block_until_ready(lg)
_dec = jax.jit(lambda p, c, t, l: T.decode_step(p, c, cfg=cfg, tokens=t, lengths=l, attn_impl=scfg.attn_impl))
padded(); per_token()  # compile
times_p, times_t = [], []
for _ in range(3):
    t0 = time.perf_counter(); padded(); times_p.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); per_token(); times_t.append(time.perf_counter() - t0)
print("TAIL", min(times_p), min(times_t))

# chaos cells: the same drain under the ServeSupervisor, once clean and
# once with a mid-drain injected fault.  The delta is the cost of
# snapshot+restore+replay; the gated invariants are zero lost requests
# and bitwise-equal tokens (benchmarks/run.py --check pins both).
from repro.serve.supervisor import ServeSupervisor, chaos_injector
for label in ("sequential", "stream_lazy"):
    eng = engines[label]
    sup = ServeSupervisor(eng)
    pristine = sup.snapshot()
    t0 = time.perf_counter()
    reqs = [sup.submit(p) for p in workload(np.random.default_rng(7))]
    sup.run_until_drained()
    clean_wall = time.perf_counter() - t0
    golden = [r.out_tokens for r in reqs]
    sup2 = ServeSupervisor(
        eng, fail_injector=chaos_injector("raise", sup.stats["rounds"] // 2))
    sup2.restore(pristine)
    t0 = time.perf_counter()
    reqs2 = [sup2.submit(p) for p in workload(np.random.default_rng(7))]
    sup2.run_until_drained()
    chaos_wall = time.perf_counter() - t0
    print("CHAOS", label, clean_wall, chaos_wall,
          sup2.stats["requests_lost"], sup2.stats["restarts"],
          [r.out_tokens for r in reqs2] == golden)
"""


def _predicted_ticks(dim: int, layers: int, batch: int) -> dict:
    """Roofline decode-tick predictions for the bench model, per kernel
    mode — recorded so BENCH_serve.json carries achieved-vs-predicted.
    Returns {} when repro isn't importable (standalone benchmark run)."""
    try:
        from repro.configs.registry import get_config, smoke_config
        from repro.roofline.analytic import predicted_tick_seconds
    except ImportError:
        return {}
    cfg = smoke_config(get_config("olmo-1b")).with_overrides(num_layers=layers)
    if dim:
        cfg = cfg.with_overrides(d_model=dim, d_ff=2 * dim, num_heads=8,
                                 head_dim=dim // 8, num_kv_heads=2,
                                 vocab_size=2048)
    return {
        mode: predicted_tick_seconds(
            cfg, batch=batch, kv_len=64,
            peak_flops_per_second=CPU_PEAK_FLOPS,
            hbm_bytes_per_second=CPU_HBM_BPS, mode=mode,
        )["total"]
        for mode in ("xla", "pallas")
    }


def run(quick: bool = True):
    rows, records = [], []
    # dim=0 keeps the smoke model's 64-dim blocks — the regime where the
    # round program's per-tick costs are amortized and the monad
    # substitution's pipelining win is measurable on 2 CPU cores.
    dim, layers = (0, 8) if quick else (384, 8)
    batches = (8, 16) if quick else (8, 16)
    for batch in batches:
        out = run_with_devices(
            SCRIPT.format(
                batch=batch,
                requests=2 * batch,
                max_new=24 if quick else 32,
                plen=16,
                chunk=16,
                dim=dim,
                layers=layers,
                round_steps=16,
                micro=2,
                engines=ENGINES,
            ),
            2,
            timeout=3000,
        )
        tail = None
        per_engine = {}
        chaos = {}
        for line in out.strip().splitlines():
            parts = line.split()
            if parts[0] == "ENGINE":
                per_engine[parts[1]] = (
                    float(parts[2]), float(parts[3]), int(parts[4])
                )
            elif parts[0] == "TAIL":
                tail = (float(parts[1]), float(parts[2]))
            elif parts[0] == "CHAOS":
                chaos[parts[1]] = (
                    float(parts[2]), float(parts[3]),
                    int(parts[4]), int(parts[5]), parts[6] == "True",
                )
        lazy_tps = None
        if "stream_lazy" in per_engine:
            w, _, tot = per_engine["stream_lazy"]
            lazy_tps = tot / w
        predicted = _predicted_ticks(dim, layers, batch)
        for label, schedule, ndev, interleave, kern in ENGINES:
            wall, ttft, total = per_engine[label]
            tps = total / wall
            # one "tick" = one decode step across the full batch; the
            # drain produces total tokens over batch-wide steps
            achieved_tick = wall * batch / total
            pred = predicted.get(kern)
            vs = (
                f",vs_lazy={tps / lazy_tps:.2f}x"
                if lazy_tps and label.startswith("stream_") and label != "stream_lazy"
                else ""
            )
            if pred:
                vs += f",roofline_tick_ms={pred*1e3:.2f}"
            rows.append(
                csv_row(
                    f"serve_{label}_b{batch}",
                    wall,
                    f"tok_per_s={tps:.1f},ttft_ms={ttft*1e3:.1f},"
                    f"devices={ndev},kernels={kern}"
                    + (f",V={interleave}" if interleave > 1 else "")
                    + vs,
                )
            )
            records.append(
                {
                    "engine": label,
                    "schedule": schedule,
                    "devices": ndev,
                    "interleave": interleave,
                    "kernels": kern,
                    "batch": batch,
                    "requests": 2 * batch,
                    "max_new": 24 if quick else 32,
                    "prompt_len": 16,
                    "dim": dim,
                    "layers": layers,
                    "round_steps": 16,
                    "layer_sequential_baseline": label == "stream_lazy",
                    "tokens_per_sec": tps,
                    "ttft_seconds": ttft,
                    "speedup_vs_layer_sequential": (
                        tps / lazy_tps if lazy_tps else None
                    ),
                    "wall_seconds": wall,
                    "achieved_tick_seconds": achieved_tick,
                    "predicted_tick_seconds": pred,
                    "tick_vs_roofline": (
                        achieved_tick / pred if pred else None
                    ),
                }
            )
        for label, (cw, xw, lost, restarts, bitwise) in chaos.items():
            # supervised-recovery cells: no tokens_per_sec on purpose —
            # the gate on these is zero-loss + bitwise, not throughput.
            rows.append(
                csv_row(
                    f"serve_chaos_{label}_b{batch}",
                    xw,
                    f"clean_s={cw:.2f},requests_lost={lost},"
                    f"restarts={restarts},bitwise={bitwise},"
                    f"overhead_ms={(xw - cw)*1e3:.0f}",
                )
            )
            records.append(
                {
                    "engine": f"chaos_{label}",
                    "schedule": "-",
                    "devices": 1,
                    "interleave": 1,
                    "kernels": "xla",
                    "batch": batch,
                    "requests": 2 * batch,
                    "dim": dim,
                    "layers": layers,
                    "requests_lost": lost,
                    "restarts": restarts,
                    "bitwise_equal": bitwise,
                    "clean_wall_seconds": cw,
                    "chaos_wall_seconds": xw,
                    "recovery_overhead_seconds": xw - cw,
                }
            )
        if tail is not None:
            rows.append(
                csv_row(
                    f"serve_prefill_tail_b{batch}",
                    tail[0],
                    f"padded_ms={tail[0]*1e3:.1f},"
                    f"per_token_ms={tail[1]*1e3:.1f},"
                    f"speedup={tail[1]/tail[0]:.1f}x",
                )
            )
            records.append(
                {
                    "engine": "prefill_tail",
                    "schedule": "-",
                    "devices": 1,
                    "interleave": 1,
                    "batch": batch,
                    "dim": dim,
                    "padded_seconds": tail[0],
                    "per_token_seconds": tail[1],
                }
            )
    run.records = records  # picked up by benchmarks.run for BENCH_serve.json
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
