"""Paper §7 ("grouping these in bigger chunks may provide better
efficiency" — proposed, untested in the paper; implemented here).

Sweeps the chunk-size knob at fixed total work on both paper algorithms:
``primes`` over primes_per_cell, ``polymul`` over terms_per_cell.  The
derived column reports speedup over the finest grain (the paper's
original cell size, K=1).
"""
from __future__ import annotations

import jax

from benchmarks._util import csv_row, timed
from repro.algorithms import polynomial as poly
from repro.algorithms import sieve


def run(quick: bool = True):
    rows = []
    # --- sieve: K primes per cell -----------------------------------------
    limit = 2000 if quick else 20000
    base = None
    for k in (1, 2, 4, 8, 16, 32):
        fn = lambda k=k: sieve.run_sieve(
            limit, block_size=256, primes_per_cell=k
        )[0]
        t, _ = timed(fn, repeats=3)
        base = base or t
        rows.append(csv_row(f"sieve_chunk{k}", t, f"speedup={base/t:.2f}x"))
    # --- polymul: G terms per cell ------------------------------------------
    power = 5 if quick else 8
    n_terms = (power + 3) * (power + 2) * (power + 1) // 6
    p2 = 2 * power
    acc = 1 << ((p2 + 3) * (p2 + 2) * (p2 + 1) // 6 - 1).bit_length()
    base = None
    for g in (1, 2, 4, 8, 14):
        cap = -(-n_terms // (g * 2)) * (g * 2)
        x = poly.fateman_poly(power, cap, 4)
        fn = jax.jit(
            lambda x, g=g: poly.times(
                x, x, num_x_chunks=2, terms_per_cell=g, acc_capacity=acc
            )
        )
        t, _ = timed(fn, x, repeats=3)
        base = base or t
        rows.append(csv_row(f"polymul_chunk{g}", t, f"speedup={base/t:.2f}x"))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
