"""Roofline table: summarize the dry-run artifacts (§Roofline source).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per cell: the three terms, the bottleneck, the useful-FLOP
ratio and roofline fraction.  Not a timing benchmark — the derived column
carries the analysis.
"""
from __future__ import annotations

import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(mesh: str | None = None):
    records = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "roofline" not in rec:  # e.g. the pipeline-demo artifact
            continue
        if mesh and rec["roofline"]["mesh"] != mesh:
            continue
        records.append(rec)
    return records


def run(quick: bool = True):
    rows = []
    for rec in load_records(mesh="pod"):
        r = rec["roofline"]
        name = f"roofline_{r['arch']}_{r['shape']}"
        derived = (
            f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.4f};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"mem_gib={rec['memory_analysis']['peak_gib']:.1f}"
        )
        rows.append(f"{name},{r['step_time_s'] * 1e6:.1f},{derived}")
    return rows


def markdown_table(mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| model/compiled | roofline frac | peak GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(mesh=mesh):
        r = rec["roofline"]
        m = rec["memory_analysis"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {m['peak_gib']:.1f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    for row in run():
        print(row)
