"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only primes,...]

Prints ``name,us_per_call,derived`` CSV.  quick mode (default) shrinks
problem sizes so the suite completes in minutes on one CPU core; --full
uses the paper's sizes (Table 1: primes to 20000/60000, Fateman ^20).

The pipeline suite additionally persists its (schedule x M) sweep —
modeled vs measured — to ``BENCH_pipeline.json`` at the repo root, the
perf-trajectory baseline future PRs diff against.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from benchmarks import (
    bench_chunking,
    bench_pipeline,
    bench_polymul,
    bench_primes,
    bench_roofline,
)

SUITES = {
    "primes": bench_primes,      # Table 1 / Fig 3
    "polymul": bench_polymul,    # Table 1 / Fig 4
    "chunking": bench_chunking,  # §7 proposal
    "pipeline": bench_pipeline,  # bubble model (DESIGN §2)
    "roofline": bench_roofline,  # §Roofline table from dry-run artifacts
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            rows = SUITES[name].run(quick=not args.full)
            for row in rows:
                print(row)
            sys.stdout.flush()
            if name == "pipeline":
                _write_pipeline_baseline(getattr(SUITES[name].run, "records", []))
        except Exception as e:  # noqa: BLE001
            failed.append((name, e))
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {[n for n, _ in failed]}")


def _write_pipeline_baseline(records: list) -> None:
    if not records:
        return
    path = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_pipeline.json"
    )
    with open(os.path.normpath(path), "w") as f:
        json.dump({"sweep": records}, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}", file=sys.stderr)


if __name__ == "__main__":
    main()
