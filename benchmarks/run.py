"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only primes,...]
    PYTHONPATH=src python -m benchmarks.run --check [--check-tolerance 0.1]

Prints ``name,us_per_call,derived`` CSV.  quick mode (default) shrinks
problem sizes so the suite completes in minutes on one CPU core; --full
uses the paper's sizes (Table 1: primes to 20000/60000, Fateman ^20).

The pipeline suite additionally persists its (schedule x M) sweep —
modeled vs measured — to ``BENCH_pipeline.json`` at the repo root, the
perf-trajectory baseline future PRs diff against.  ``--check`` is the
enforcement: it runs a fresh paired sweep, diffs every
(schedule, devices, V, M) cell against the persisted baseline, and
exits nonzero if any cell's wall-clock regressed by more than
``--check-tolerance`` (default 10%) — the perf gate perf-sensitive PRs
run before merging.  ``--check`` does not overwrite the baseline;
re-run without it to re-baseline intentionally.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from benchmarks import (
    bench_chunking,
    bench_pipeline,
    bench_polymul,
    bench_primes,
    bench_roofline,
)

SUITES = {
    "primes": bench_primes,      # Table 1 / Fig 3
    "polymul": bench_polymul,    # Table 1 / Fig 4
    "chunking": bench_chunking,  # §7 proposal
    "pipeline": bench_pipeline,  # bubble model (DESIGN §2)
    "roofline": bench_roofline,  # §Roofline table from dry-run artifacts
}

BASELINE_PATH = os.path.normpath(
    os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_pipeline.json"
    )
)


def _cell_key(record: dict) -> tuple:
    """Identity of one sweep cell: compare like against like."""
    return (
        record["schedule"],
        record["devices"],
        record["interleave"],
        record["num_microbatches"],
        record["dim"],
        record["rows"],
    )


def check_regressions(
    baseline: list[dict], fresh: list[dict], tolerance: float
) -> list[dict]:
    """Cells whose measured wall-clock regressed past ``tolerance``.

    Compares only cells present in both sweeps with identical problem
    sizes (so a --check quick run never diffs against a --full
    baseline).  Pure so the gate is unit-testable offline.
    """
    base = {_cell_key(r): r["measured_seconds"] for r in baseline}
    regressions = []
    for rec in fresh:
        key = _cell_key(rec)
        if key not in base:
            continue
        before, after = base[key], rec["measured_seconds"]
        if after > before * (1.0 + tolerance):
            regressions.append(
                {
                    "schedule": rec["schedule"],
                    "devices": rec["devices"],
                    "interleave": rec["interleave"],
                    "num_microbatches": rec["num_microbatches"],
                    "baseline_seconds": before,
                    "measured_seconds": after,
                    "ratio": after / before,
                }
            )
    return regressions


def run_check(tolerance: float, full: bool) -> int:
    if not os.path.exists(BASELINE_PATH):
        print(
            f"no baseline at {BASELINE_PATH}; run the pipeline suite once "
            "without --check to create it",
            file=sys.stderr,
        )
        return 2
    with open(BASELINE_PATH) as f:
        baseline = json.load(f)["sweep"]
    for row in bench_pipeline.run(quick=not full):
        print(row)
    fresh = getattr(bench_pipeline.run, "records", [])
    compared = {
        _cell_key(r) for r in fresh
    } & {_cell_key(r) for r in baseline}
    regressions = check_regressions(baseline, fresh, tolerance)
    print(
        f"# --check: {len(compared)} cells compared against baseline, "
        f"{len(regressions)} regressed beyond {tolerance:.0%}",
        file=sys.stderr,
    )
    for r in regressions:
        print(
            f"# REGRESSION {r['schedule']} D={r['devices']} "
            f"V={r['interleave']} M={r['num_microbatches']}: "
            f"{r['baseline_seconds']*1e3:.2f}ms -> "
            f"{r['measured_seconds']*1e3:.2f}ms ({r['ratio']:.2f}x)",
            file=sys.stderr,
        )
    if not compared:
        print("# --check: no comparable cells (size mismatch?)", file=sys.stderr)
        return 2
    return 1 if regressions else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument(
        "--check",
        action="store_true",
        help="diff a fresh pipeline sweep against BENCH_pipeline.json and "
        "exit nonzero on wall-clock regression (the perf gate)",
    )
    ap.add_argument(
        "--check-tolerance",
        type=float,
        default=0.10,
        help="relative slowdown tolerated per sweep cell (default 0.10)",
    )
    args = ap.parse_args()

    if args.check:
        raise SystemExit(run_check(args.check_tolerance, args.full))

    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            rows = SUITES[name].run(quick=not args.full)
            for row in rows:
                print(row)
            sys.stdout.flush()
            if name == "pipeline":
                _write_pipeline_baseline(getattr(SUITES[name].run, "records", []))
        except Exception as e:  # noqa: BLE001
            failed.append((name, e))
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {[n for n, _ in failed]}")


def _write_pipeline_baseline(records: list) -> None:
    if not records:
        return
    with open(BASELINE_PATH, "w") as f:
        json.dump({"sweep": records}, f, indent=2)
    print(f"# wrote {BASELINE_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()
