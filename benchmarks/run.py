"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only primes,...]
    PYTHONPATH=src python -m benchmarks.run --check [--check-tolerance 0.1]

Prints ``name,us_per_call,derived`` CSV.  quick mode (default) shrinks
problem sizes so the suite completes in minutes on one CPU core; --full
uses the paper's sizes (Table 1: primes to 20000/60000, Fateman ^20).

The pipeline suite additionally persists its (schedule x M) sweep —
modeled vs measured — to ``BENCH_pipeline.json`` at the repo root, the
perf-trajectory baseline future PRs diff against; the serve suite
persists ``BENCH_serve.json`` (tokens/sec + TTFT) and the train suite
``BENCH_train.json`` (value_and_grad step time per schedule x M,
autodiff vs planned backward).  ``--check`` is the enforcement: it
runs a fresh paired sweep, diffs every cell against the persisted
baselines (pipeline wall-clock, serve throughput, train wall-clock),
and exits nonzero if any cell regressed by more than
``--check-tolerance`` (default 10%) — the perf gate perf-sensitive PRs
run before merging.  ``--check --suite serve`` gates only the named
suite(s); a requested gate with no baseline exits 2 with the exact
``--suite`` command that creates one (never a KeyError).  ``--check``
does not overwrite the baselines; re-run without it to re-baseline
intentionally.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from benchmarks import (
    bench_chunking,
    bench_pipeline,
    bench_polymul,
    bench_primes,
    bench_roofline,
    bench_serve,
    bench_train,
)

SUITES = {
    "primes": bench_primes,      # Table 1 / Fig 3
    "polymul": bench_polymul,    # Table 1 / Fig 4
    "chunking": bench_chunking,  # §7 proposal
    "pipeline": bench_pipeline,  # bubble model (DESIGN §2)
    "roofline": bench_roofline,  # §Roofline table from dry-run artifacts
    "serve": bench_serve,        # Stream-shaped serving (tok/s + TTFT)
    "train": bench_train,        # autodiff vs planned backward step time
}

_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
)
BASELINE_PATH = os.path.join(_ROOT, "BENCH_pipeline.json")
SERVE_BASELINE_PATH = os.path.join(_ROOT, "BENCH_serve.json")
TRAIN_BASELINE_PATH = os.path.join(_ROOT, "BENCH_train.json")


def _cell_key(record: dict) -> tuple:
    """Identity of one sweep cell: compare like against like."""
    return (
        record["schedule"],
        record["devices"],
        record["interleave"],
        record["num_microbatches"],
        record["dim"],
        record["rows"],
    )


def _regressions(
    baseline: list[dict],
    fresh: list[dict],
    key_fn,
    metric: str,
    tolerance: float,
    higher_is_better: bool,
    report_fields: tuple[str, ...],
) -> list[dict]:
    """Generic directional gate: cells present in both sweeps whose
    ``metric`` moved the wrong way past ``tolerance``.  One compare
    loop serves wall-clock (lower is better) and throughput (higher is
    better) gates; pure so both are unit-testable offline."""
    base = {key_fn(r): r[metric] for r in baseline if metric in r}
    regressions = []
    for rec in fresh:
        if metric not in rec:
            continue
        key = key_fn(rec)
        if key not in base:
            continue
        before, after = base[key], rec[metric]
        bad = (
            after < before * (1.0 - tolerance)
            if higher_is_better
            else after > before * (1.0 + tolerance)
        )
        if bad:
            out = {f: rec[f] for f in report_fields if f in rec}
            out[f"baseline_{metric}"] = before
            out[f"measured_{metric}"] = after
            out["ratio"] = after / before
            regressions.append(out)
    return regressions


def check_regressions(
    baseline: list[dict], fresh: list[dict], tolerance: float
) -> list[dict]:
    """Pipeline cells whose measured wall-clock regressed past
    ``tolerance``.  Compares only cells present in both sweeps with
    identical problem sizes (so a --check quick run never diffs against
    a --full baseline)."""
    out = _regressions(
        baseline, fresh, _cell_key, "measured_seconds", tolerance,
        higher_is_better=False,
        report_fields=("schedule", "devices", "interleave", "num_microbatches"),
    )
    for r in out:  # keep the historical report-field names
        r["baseline_seconds"] = r.pop("baseline_measured_seconds")
        r["measured_seconds"] = r["measured_measured_seconds"]
        del r["measured_measured_seconds"]
    return out


def _serve_cell_key(record: dict) -> tuple:
    """Identity of one serve sweep cell.  ``kernels`` defaults to "xla"
    so baselines written before the kernel-dispatch axis existed keep
    gating the xla cells."""
    return (
        record.get("engine"),
        record.get("schedule"),
        record.get("devices"),
        record.get("interleave"),
        record.get("kernels", "xla"),
        record.get("batch"),
        record.get("dim"),
        record.get("max_new"),
    )


def check_serve_regressions(
    baseline: list[dict], fresh: list[dict], tolerance: float
) -> list[dict]:
    """Serve cells whose tokens/sec regressed past ``tolerance`` —
    the throughput-directional (higher is better) instance of the
    shared gate — plus the chaos invariants: every fresh ``chaos_*``
    cell must report ``requests_lost == 0`` and ``bitwise_equal``
    recovery.  The chaos check is absolute (fresh-run-only, no
    baseline needed): losing a request under fault injection is a
    correctness bug at any tolerance."""
    out = _regressions(
        baseline, fresh, _serve_cell_key, "tokens_per_sec", tolerance,
        higher_is_better=True, report_fields=("engine", "batch"),
    )
    for r in out:
        r["baseline_tok_s"] = r.pop("baseline_tokens_per_sec")
        r["measured_tok_s"] = r.pop("measured_tokens_per_sec")
    for rec in fresh:
        if "requests_lost" not in rec:
            continue
        if rec["requests_lost"] != 0 or rec.get("bitwise_equal") is False:
            out.append(
                {
                    "engine": rec.get("engine"),
                    "batch": rec.get("batch"),
                    "requests_lost": rec["requests_lost"],
                    "bitwise_equal": rec.get("bitwise_equal"),
                }
            )
    return out


def _train_cell_key(record: dict) -> tuple:
    """Identity of one train sweep cell (schedule x backward x M)."""
    return (
        record.get("schedule"),
        record.get("backward"),
        record.get("devices"),
        record.get("interleave"),
        record.get("num_microbatches"),
        record.get("dim"),
        record.get("rows"),
    )


def check_train_regressions(
    baseline: list[dict], fresh: list[dict], tolerance: float
) -> list[dict]:
    """Train-step cells whose wall-clock regressed past ``tolerance`` —
    the autodiff-vs-planned backward sweep instance of the shared
    gate."""
    out = _regressions(
        baseline, fresh, _train_cell_key, "measured_seconds", tolerance,
        higher_is_better=False,
        report_fields=("schedule", "backward", "num_microbatches"),
    )
    for r in out:
        r["baseline_seconds"] = r.pop("baseline_measured_seconds")
        r["measured_seconds"] = r.pop("measured_measured_seconds")
    return out


# The gated suites: (module, baseline path, cell-key fn, comparison fn,
# the metric a record must carry to be comparable, one-line regression
# formatter).  One table + one driver instead of a copy-pasted block
# per suite; adding a gate is adding a row.
GATES = {
    "pipeline": (
        lambda: bench_pipeline, BASELINE_PATH, _cell_key, check_regressions,
        "measured_seconds",
        lambda r: (
            f"# REGRESSION pipeline {r['schedule']} D={r['devices']} "
            f"V={r['interleave']} M={r['num_microbatches']}: "
            f"{r['baseline_seconds']*1e3:.2f}ms -> "
            f"{r['measured_seconds']*1e3:.2f}ms ({r['ratio']:.2f}x)"
        ),
    ),
    "serve": (
        lambda: bench_serve, SERVE_BASELINE_PATH, _serve_cell_key,
        check_serve_regressions, "tokens_per_sec",
        lambda r: (
            f"# CHAOS VIOLATION serve {r['engine']} b={r['batch']}: "
            f"requests_lost={r['requests_lost']} "
            f"bitwise_equal={r['bitwise_equal']}"
            if "requests_lost" in r
            else f"# REGRESSION serve {r['engine']} b={r['batch']}: "
            f"{r['baseline_tok_s']:.1f} -> {r['measured_tok_s']:.1f} "
            f"tok/s ({r['ratio']:.2f}x)"
        ),
    ),
    "train": (
        lambda: bench_train, TRAIN_BASELINE_PATH, _train_cell_key,
        check_train_regressions, "measured_seconds",
        lambda r: (
            f"# REGRESSION train {r['schedule']} {r['backward']} "
            f"M={r['num_microbatches']}: "
            f"{r['baseline_seconds']*1e3:.2f}ms -> "
            f"{r['measured_seconds']*1e3:.2f}ms ({r['ratio']:.2f}x)"
        ),
    ),
}


def _load_baseline(label: str, path: str) -> list | None:
    """Load one gate's persisted sweep, or explain exactly how to create
    it.  A missing file or a file without a ``sweep`` key (a corrupt or
    hand-edited baseline) both return None after printing the fix — the
    gate must never die with a KeyError."""
    if not os.path.exists(path):
        print(
            f"# --check {label}: no baseline at {path}; run "
            f"`python -m benchmarks.run --suite {label}` first",
            file=sys.stderr,
        )
        return None
    with open(path) as f:
        try:
            data = json.load(f)
        except json.JSONDecodeError as e:
            print(
                f"# --check {label}: unreadable baseline {path} ({e}); "
                f"re-run `python -m benchmarks.run --suite {label}`",
                file=sys.stderr,
            )
            return None
    sweep = data.get("sweep")
    if not isinstance(sweep, list):
        print(
            f"# --check {label}: baseline {path} has no 'sweep' list; "
            f"re-run `python -m benchmarks.run --suite {label}`",
            file=sys.stderr,
        )
        return None
    return sweep


def _run_gate(label: str, tolerance: float, full: bool) -> int:
    """Run one suite fresh and diff it against its persisted baseline.

    Returns 0 clean, 1 on regression, 2 when nothing was comparable
    (size mismatch between the fresh run and the baseline, or no usable
    baseline).
    """
    module_fn, path, key_fn, check_fn, metric, fmt = GATES[label]
    module = module_fn()
    baseline = _load_baseline(label, path)
    if baseline is None:
        return 2
    for row in module.run(quick=not full):
        print(row)
    fresh = getattr(module.run, "records", [])
    compared = {
        key_fn(r) for r in fresh if metric in r
    } & {key_fn(r) for r in baseline if metric in r}
    regressions = check_fn(baseline, fresh, tolerance)
    print(
        f"# --check {label}: {len(compared)} cells compared, "
        f"{len(regressions)} regressed beyond {tolerance:.0%}",
        file=sys.stderr,
    )
    # Violations outrank incomparability: a chaos cell losing requests
    # must fail the gate even when no throughput cell matched the
    # baseline (the chaos invariants are fresh-run-only).
    for r in regressions:
        print(fmt(r), file=sys.stderr)
    if regressions:
        return 1
    if not compared:
        print(
            f"# --check {label}: no comparable cells (size mismatch?)",
            file=sys.stderr,
        )
        return 2
    return 0


def run_check(tolerance: float, full: bool, only: str | None = None) -> int:
    """The perf gate.  ``only`` (from --only/--suite) restricts which
    gates run; an explicitly requested gate with no baseline is an error
    (rc 2) with a message naming the --suite run that creates it, while
    un-requested ride-along gates merely note the skip."""
    if only is not None:
        labels = [n for n in only.split(",") if n]
        unknown = [n for n in labels if n not in GATES]
        if unknown:
            print(
                f"# --check: no gate for suite(s) {unknown}; gated suites "
                f"are {list(GATES)}",
                file=sys.stderr,
            )
            return 2
    else:
        labels = list(GATES)
    # Every requested gate runs — one incomparable baseline must not
    # mask a real regression in a later suite.  Regression (1) outranks
    # incomparability (2) in the aggregate exit code.
    rcs = []
    for label in labels:
        if (
            only is None
            and label != "pipeline"
            and not os.path.exists(GATES[label][1])
        ):
            # Ride-along gates only gate once baselined — but say so.
            print(
                f"# --check {label}: skipped (no baseline; run "
                f"`python -m benchmarks.run --suite {label}` to start "
                "gating it)",
                file=sys.stderr,
            )
            continue
        rcs.append(_run_gate(label, tolerance, full))
    if 1 in rcs:
        return 1
    if 2 in rcs or not rcs:
        return 2
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument(
        "--suite", default=None,
        help="alias of --only (e.g. --suite serve)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="diff a fresh pipeline sweep against BENCH_pipeline.json and "
        "exit nonzero on wall-clock regression (the perf gate)",
    )
    ap.add_argument(
        "--check-tolerance",
        type=float,
        default=0.10,
        help="relative slowdown tolerated per sweep cell (default 0.10)",
    )
    args = ap.parse_args()

    if args.check:
        raise SystemExit(
            run_check(args.check_tolerance, args.full, args.only or args.suite)
        )

    only = args.only or args.suite
    names = only.split(",") if only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            rows = SUITES[name].run(quick=not args.full)
            for row in rows:
                print(row)
            sys.stdout.flush()
            if name in GATES:
                _write_baseline(
                    GATES[name][1], getattr(SUITES[name].run, "records", [])
                )
        except Exception as e:  # noqa: BLE001
            failed.append((name, e))
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {[n for n, _ in failed]}")


def _write_baseline(path: str, records: list) -> None:
    if not records:
        return
    payload = {"sweep": records}
    try:
        import subprocess

        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=_ROOT, timeout=10, stdin=subprocess.DEVNULL,
        )
        if sha.returncode == 0:
            payload["git_sha"] = sha.stdout.strip()
    except OSError:
        pass  # not a git checkout / git unavailable: baseline still valid
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
